//! Failure injection: the runtime must fail loudly and descriptively, not
//! crash or compute garbage, when artifacts are missing/corrupt or configs
//! are inconsistent.

use std::io::Write;

use nekbone::config::RunConfig;
use nekbone::coordinator::Nekbone;
use nekbone::error::Error;
use nekbone::runtime::{Manifest, XlaRuntime};

fn tmp_dir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("nekbone-test-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn missing_manifest_is_io_error() {
    let dir = tmp_dir("missing");
    let err = Manifest::load(&dir).unwrap_err();
    assert!(matches!(err, Error::Io { .. }), "{err}");
}

#[test]
fn corrupt_manifest_is_json_error() {
    let dir = tmp_dir("corrupt-json");
    std::fs::write(dir.join("manifest.json"), b"{not json").unwrap();
    let err = Manifest::load(&dir).unwrap_err();
    assert!(matches!(err, Error::Json { .. }), "{err}");
}

#[test]
fn manifest_without_artifacts_key_rejected() {
    let dir = tmp_dir("no-key");
    std::fs::write(dir.join("manifest.json"), b"{\"format\": 1}").unwrap();
    let err = Manifest::load(&dir).unwrap_err();
    assert!(matches!(err, Error::Artifact(_)), "{err}");
}

#[test]
fn corrupt_hlo_text_fails_at_compile() {
    let dir = tmp_dir("corrupt-hlo");
    let manifest = r#"{"artifacts": [
      {"name": "ax_layered_n10_e64", "kind": "ax", "variant": "layered",
       "n": 10, "chunk": 64, "dtype": "float64",
       "file": "bad.hlo.txt", "num_args": 3, "tupled": false,
       "arg_shapes": [[64,10,10,10],[10,10],[64,6,10,10,10]]}
    ]}"#;
    std::fs::write(dir.join("manifest.json"), manifest).unwrap();
    let mut f = std::fs::File::create(dir.join("bad.hlo.txt")).unwrap();
    f.write_all(b"HloModule garbage\nENTRY oops { this is not hlo }\n").unwrap();
    drop(f);

    let rt = match XlaRuntime::new(&dir) {
        Ok(rt) => rt,
        // The offline xla stub cannot construct a PJRT client at all; the
        // compile-rejects-garbage property needs the real runtime.
        Err(e) => {
            eprintln!("skipping: PJRT client unavailable ({e})");
            return;
        }
    };
    let meta = rt.manifest().find("ax_layered_n10_e64").unwrap().clone();
    assert!(rt.compile(&meta).is_err(), "corrupt HLO must not compile");
}

#[test]
fn xla_backend_without_artifact_reports_artifact_error() {
    let dir = tmp_dir("empty-manifest");
    std::fs::write(dir.join("manifest.json"), b"{\"artifacts\": []}").unwrap();
    let cfg = RunConfig {
        nelt: 8,
        n: 10,
        niter: 5,
        artifacts_dir: dir.to_str().unwrap().into(),
        ..Default::default()
    };
    let err = Nekbone::builder(cfg).operator("xla-layered").build().err().unwrap();
    match err {
        Error::Artifact(msg) => assert!(msg.contains("layered"), "{msg}"),
        other => panic!("expected Artifact error, got {other}"),
    }
}

#[test]
fn fused_backend_without_artifact_reports_artifact_error() {
    // The fused operator checks its cg_iter artifact the same way.
    let dir = tmp_dir("empty-manifest-fused");
    std::fs::write(dir.join("manifest.json"), b"{\"artifacts\": []}").unwrap();
    let cfg = RunConfig {
        nelt: 8,
        n: 10,
        niter: 5,
        artifacts_dir: dir.to_str().unwrap().into(),
        ..Default::default()
    };
    let err = Nekbone::builder(cfg).operator("xla-fused").build().err().unwrap();
    match err {
        Error::Artifact(msg) => assert!(msg.contains("cg_iter"), "{msg}"),
        other => panic!("expected Artifact error, got {other}"),
    }
}

#[test]
fn xla_backend_without_manifest_reports_io_error() {
    // No artifacts dir at all: the operator's setup surfaces the missing
    // manifest, not a panic.
    let cfg = RunConfig {
        nelt: 8,
        n: 10,
        niter: 5,
        artifacts_dir: "/nonexistent/nowhere".into(),
        ..Default::default()
    };
    let err = Nekbone::builder(cfg).operator("xla-layered").build().err().unwrap();
    assert!(matches!(err, Error::Io { .. }), "{err}");
}

#[test]
fn cpu_backend_ignores_artifacts_entirely() {
    // No artifacts dir at all: CPU operators must still run.
    let cfg = RunConfig {
        nelt: 8,
        n: 4,
        niter: 5,
        artifacts_dir: "/nonexistent/nowhere".into(),
        ..Default::default()
    };
    let mut app = Nekbone::builder(cfg).operator("cpu-layered").build().unwrap();
    app.run().unwrap();
}

#[test]
fn unknown_operator_reports_config_error_with_names() {
    let cfg = RunConfig { nelt: 8, n: 4, niter: 5, ..Default::default() };
    let err = Nekbone::builder(cfg).operator("tpu-layered").build().err().unwrap();
    match err {
        Error::Config(msg) => {
            assert!(msg.contains("tpu-layered"), "{msg}");
            assert!(msg.contains("cpu-layered"), "must list registered names: {msg}");
            assert!(msg.contains("xla-layered"), "must list registered names: {msg}");
        }
        other => panic!("expected Config error, got {other}"),
    }
}

#[test]
fn config_cross_validation() {
    // ranks > nelt is caught before any setup work.
    let cfg = RunConfig { nelt: 4, ranks: 8, ..Default::default() };
    assert!(matches!(cfg.validate(), Err(Error::Config(_))));
}

#[test]
fn set_rhs_length_mismatch() {
    let cfg = RunConfig { nelt: 8, n: 4, niter: 5, ..Default::default() };
    let mut app = Nekbone::builder(cfg).operator("cpu-layered").build().unwrap();
    assert!(app.set_rhs(&[1.0, 2.0]).is_err());
}
