//! Registry-wide operator conformance suite, organised around declared
//! **precision tiers**.
//!
//! Every test here enumerates [`OperatorRegistry::default`] — never a
//! hand-written name list — and subjects **every** registered operator to
//! the shared contract at the accuracy its spec declares:
//!
//! * [`PrecisionTier::Exact`] — bitwise equal to the `ax_layered`
//!   reference schedule (the layered/specialized family reorders
//!   nothing).
//! * [`PrecisionTier::FmaBand`] — within `1e-11` of the Listing-1 oracle
//!   (FMA contraction and parallel partitioning reassociate, f64 storage
//!   throughout).
//! * [`PrecisionTier::ReducedStorage`] — within the f32-storage band
//!   `1e-5 · (|want| + max|want|)`: the geometric factors round to f32
//!   once at setup, all arithmetic still accumulates in f64.
//!
//! The tier is *claimed* metadata, so the suite also polices the claim
//! both ways: only `-f32`-named operators may claim `ReducedStorage`, and
//! every `-f32` operator must claim it — a future registration can
//! neither dodge the loose band nor hide behind it.
//!
//! Coverage is enforced, not assumed: the only legitimate skip is an
//! artifact-backed operator on a host without AOT artifacts, and that
//! exemption comes from the registry's own `needs_artifacts` metadata —
//! an artifact-free operator can never be skipped, and the suite fails if
//! tested + artifact-gated does not equal the whole registry.

use std::collections::BTreeSet;

use nekbone::config::RunConfig;
use nekbone::coordinator::Nekbone;
use nekbone::operators::{
    ax_bytes_moved, ax_bytes_moved_assembled, ax_bytes_moved_stored, ax_flops, ax_layered,
    ax_layered_store, ax_naive, fused_ax_flops, OperatorCtx, OperatorRegistry, PrecisionTier,
};
use nekbone::proputil::{assert_allclose, assert_pap_close};
use nekbone::rng::Rng;
use nekbone::solver::glsc3;

mod util;
use crate::util::{assert_within_band, inputs, REDUCED_BAND};

fn artifacts_dir() -> &'static str {
    concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")
}

fn artifacts_present() -> bool {
    std::path::Path::new(artifacts_dir()).join("manifest.json").exists()
}

/// Run `check(registry, name)` on every canonical operator in the default
/// registry, then assert nothing was skipped: the tested set plus the
/// artifact-gated set must be exactly the registry, and only operators
/// whose spec declares `needs_artifacts` may ever land in the gated set.
fn for_every_operator(mut check: impl FnMut(&OperatorRegistry, &str)) {
    let registry = OperatorRegistry::default();
    let all: BTreeSet<String> = registry.names().into_iter().collect();
    assert!(!all.is_empty(), "default registry is empty");
    let mut tested = BTreeSet::new();
    let mut gated = BTreeSet::new();
    for name in &all {
        let spec = registry.resolve(name).expect("canonical names resolve");
        assert_eq!(&spec.name, name, "resolve must round-trip the canonical name");
        if spec.needs_artifacts && !artifacts_present() {
            gated.insert(name.clone());
            continue;
        }
        check(&registry, name);
        tested.insert(name.clone());
    }
    let covered: BTreeSet<String> = tested.union(&gated).cloned().collect();
    assert_eq!(covered, all, "conformance suite skipped a registered operator");
    for name in &gated {
        assert!(
            registry.resolve(name).unwrap().needs_artifacts,
            "{name} was gated without declaring an artifact requirement"
        );
    }
    assert!(!tested.is_empty(), "conformance suite exercised no operator at all");
}

fn ctx<'a>(n: usize, nelt: usize, d: &'a [f64], g: &'a [f64], c: &'a [f64]) -> OperatorCtx<'a> {
    util::ctx(n, nelt, 0, artifacts_dir(), d, g, c)
}

#[test]
fn every_operator_agrees_at_its_declared_tier() {
    // Across degrees and element counts, every registered operator's w
    // must match the Listing-1 oracle at the accuracy its spec claims —
    // and the Exact tier additionally bit-for-bit against the layered
    // reference schedule (`cpu-naive` is itself enumerated and thus
    // compared against the raw kernel it wraps).
    for (case, &(n, nelt)) in [(2usize, 3usize), (3, 2), (5, 3), (10, 2)].iter().enumerate() {
        let (u, d, g, c) = inputs(0xC0F0 + case as u64, n, nelt);
        let np = n * n * n;
        let mut want = vec![0.0; nelt * np];
        ax_naive(n, nelt, &u, &d, &g, &mut want);
        let mut want_layered = vec![0.0; nelt * np];
        ax_layered(n, nelt, &u, &d, &g, &mut want_layered);
        for_every_operator(|registry, name| {
            let tier = registry.resolve(name).unwrap().tier;
            let mut op = registry.build(name, &ctx(n, nelt, &d, &g, &c)).unwrap();
            let mut w = vec![123.0; nelt * np]; // poisoned
            op.apply(&u, &mut w).unwrap();
            match tier {
                PrecisionTier::Exact => {
                    for (i, (&gi, &wi)) in w.iter().zip(&want_layered).enumerate() {
                        assert_eq!(
                            gi.to_bits(),
                            wi.to_bits(),
                            "{name}[{i}]: Exact tier must be bitwise layered ({gi} vs {wi})"
                        );
                    }
                    assert_allclose(&w, &want, 1e-11, 1e-11);
                }
                PrecisionTier::FmaBand => assert_allclose(&w, &want, 1e-11, 1e-11),
                PrecisionTier::ReducedStorage => {
                    assert_within_band(&w, &want, REDUCED_BAND, name)
                }
            }
        });
    }
}

#[test]
fn reduced_storage_claims_match_the_f32_naming_contract() {
    // The tier is registry metadata (available even for artifact-gated
    // operators), so this check runs over the *whole* registry: the loose
    // band is claimable only by operators that advertise reduced storage
    // in their name, and every advertised one must claim it.
    let registry = OperatorRegistry::default();
    let names = registry.names();
    assert!(names.iter().any(|n| n.ends_with("-f32")), "registry lost the f32 family");
    for name in &names {
        let spec = registry.resolve(name).unwrap();
        assert_eq!(
            spec.tier == PrecisionTier::ReducedStorage,
            name.ends_with("-f32"),
            "{name}: tier {:?} does not match the -f32 naming contract",
            spec.tier
        );
    }
}

#[test]
fn fused_operators_honor_the_pap_contract() {
    // `last_pap` is None before the first apply, equals glsc3(w, c, u) of
    // the operator's own output after it (tolerance scaled by the terms'
    // magnitude so cancellation cannot mask a real error), and is
    // bit-reproducible across applies. This holds at every tier — f32
    // storage perturbs w, but the fused reduction runs in f64 over the
    // operator's own w, so the 1e-12 agreement is precision-independent.
    // Unfused operators must report None throughout.
    let (n, nelt) = (4, 3);
    let (u, d, g, c) = inputs(0xC0F1, n, nelt);
    let np = n * n * n;
    for_every_operator(|registry, name| {
        let mut op = registry.build(name, &ctx(n, nelt, &d, &g, &c)).unwrap();
        assert_eq!(op.last_pap(), None, "{name}: pap must be None before the first apply");
        let mut w = vec![0.0; nelt * np];
        op.apply(&u, &mut w).unwrap();
        if op.is_fused() {
            let pap = op.last_pap().unwrap_or_else(|| {
                panic!("{name}: fused apply must produce a pap")
            });
            let want = glsc3(&w, &c, &u);
            assert_pap_close(pap, want, &w, &c, &u, 1e-12, name);
            let mut w2 = vec![0.0; nelt * np];
            op.apply(&u, &mut w2).unwrap();
            assert_eq!(w2, w, "{name}: apply must be deterministic");
            let pap2 = op.last_pap().unwrap();
            assert_eq!(pap.to_bits(), pap2.to_bits(), "{name}: pap must be reproducible");
        } else {
            assert_eq!(op.last_pap(), None, "{name}: unfused operators never report a pap");
        }
    });
}

#[test]
fn flops_and_bytes_follow_eq1_stream_accounting() {
    // The roofline places operators by flops()/bytes_moved(); both hooks
    // must report the Eq. (1) count for the operator's fusion class and
    // *stored width* (the six geometric-factor streams shrink to 4 bytes
    // per point on the ReducedStorage tier; the flop count never changes)
    // — and zero before setup, so a blank operator can't fake a placement.
    let (n, nelt) = (5, 3);
    let (_u, d, g, c) = inputs(0xC0F2, n, nelt);
    for_every_operator(|registry, name| {
        let tier = registry.resolve(name).unwrap().tier;
        let blank = registry.create(name).unwrap();
        assert_eq!(blank.flops(), 0, "{name}: flops before setup");
        assert_eq!(blank.bytes_moved(), 0, "{name}: bytes before setup");
        let op = registry.build(name, &ctx(n, nelt, &d, &g, &c)).unwrap();
        let want_flops =
            if op.is_fused() { fused_ax_flops(n, nelt) } else { ax_flops(n, nelt) };
        assert_eq!(op.flops(), want_flops, "{name}: flops() off the Eq. (1) count");
        let stored = if tier == PrecisionTier::ReducedStorage { 4 } else { 8 };
        let want_bytes = ax_bytes_moved_stored(n, nelt, op.is_fused(), stored);
        assert_eq!(op.bytes_moved(), want_bytes, "{name}: bytes_moved() off stream accounting");
        if stored == 8 {
            assert_eq!(
                want_bytes,
                ax_bytes_moved(n, nelt, op.is_fused()),
                "{name}: the f64 wrapper must agree with the stored-width accounting"
            );
        } else {
            assert!(
                want_bytes < ax_bytes_moved(n, nelt, op.is_fused()),
                "{name}: reduced storage must shrink the stream traffic"
            );
        }
    });
}

#[test]
fn labels_round_trip_through_the_registry() {
    // A label printed in any report or bench must parse back to the same
    // operator — before and after setup.
    let (n, nelt) = (3, 2);
    let (_u, d, g, c) = inputs(0xC0F3, n, nelt);
    for_every_operator(|registry, name| {
        let blank = registry.create(name).unwrap();
        assert_eq!(blank.label(), name, "{name}: blank label is not canonical");
        let op = registry.build(name, &ctx(n, nelt, &d, &g, &c)).unwrap();
        assert_eq!(op.label(), name, "{name}: setup changed the label");
        assert_eq!(
            registry.resolve(&op.label()).unwrap().name,
            name,
            "{name}: label does not resolve back"
        );
    });
}

#[test]
fn every_operator_runs_full_cg_to_its_tier_residual() {
    // End to end: mesh, dssum, mask, CG. Every registered operator must
    // reproduce its tier's reference residual trajectory: f64 operators
    // track `cpu-naive`, ReducedStorage operators track `cpu-layered-f32`
    // (they solve the system whose factors rounded once — a different,
    // nearby system), each to 1e-9.
    let cfg = RunConfig {
        nelt: 8,
        n: 4,
        niter: 30,
        artifacts_dir: artifacts_dir().to_string(),
        ..RunConfig::default()
    };
    let reference = |op: &str| {
        Nekbone::builder(cfg.clone()).operator(op).build().unwrap().run().unwrap()
    };
    let want = reference("cpu-naive");
    let want_f32 = reference("cpu-layered-f32");
    assert!(want.final_residual.is_finite());
    assert!(want_f32.final_residual.is_finite());
    for_every_operator(|registry, name| {
        let tier = registry.resolve(name).unwrap().tier;
        let mut app = Nekbone::builder(cfg.clone()).operator(name).build().unwrap();
        let got = app.run().unwrap();
        assert_eq!(got.backend, name, "report label must be the registry name");
        assert_eq!(got.iterations, cfg.niter, "{name}: iteration count");
        let base =
            if tier == PrecisionTier::ReducedStorage { &want_f32 } else { &want };
        let denom = base.final_residual.abs().max(1e-30);
        assert!(
            (got.final_residual - base.final_residual).abs() / denom < 1e-9,
            "{name}: residual {} vs reference {}",
            got.final_residual,
            base.final_residual
        );
    });
}

#[test]
fn f32_spec_cg_converges_to_the_same_rtol_in_comparable_iterations() {
    // The reduced-storage pipeline as a user would run it: `cpu-spec-f32`
    // must reach the same early-exit tolerance as `cpu-spec`, in a
    // comparable number of iterations — storage rounding perturbs the
    // operator, it must not stall the solve.
    let mk = || RunConfig {
        nelt: 8,
        n: 5,
        niter: 500,
        rtol: Some(1e-8),
        artifacts_dir: artifacts_dir().to_string(),
        ..RunConfig::default()
    };
    let f64_rep =
        Nekbone::builder(mk()).operator("cpu-spec").build().unwrap().run().unwrap();
    let f32_rep =
        Nekbone::builder(mk()).operator("cpu-spec-f32").build().unwrap().run().unwrap();
    assert!(f64_rep.iterations < 500, "reference solve must exit on rtol");
    assert!(f32_rep.iterations < 500, "f32 solve must exit on rtol");
    assert!(f64_rep.final_residual <= 1e-8);
    assert!(f32_rep.final_residual <= 1e-8);
    let slack = (f64_rep.iterations / 5).max(5);
    assert!(
        f32_rep.iterations <= f64_rep.iterations + slack,
        "f32 storage must not stall CG: {} vs {} iterations",
        f32_rep.iterations,
        f64_rep.iterations
    );
}

#[test]
fn coverage_cannot_be_dodged_by_an_artifact_free_operator() {
    // The enforcement mechanism itself: an artifact-free operator that a
    // check closure never reaches must fail the suite. Simulated by
    // asserting the gated set is exactly the artifact-backed names when
    // artifacts are absent (and empty when they are present).
    let registry = OperatorRegistry::default();
    let artifact_backed: BTreeSet<String> = registry
        .names()
        .into_iter()
        .filter(|name| registry.resolve(name).unwrap().needs_artifacts)
        .collect();
    let mut seen = BTreeSet::new();
    for_every_operator(|_registry, name| {
        seen.insert(name.to_string());
    });
    let all: BTreeSet<String> = registry.names().into_iter().collect();
    let expected: BTreeSet<String> = if artifacts_present() {
        all
    } else {
        all.difference(&artifact_backed).cloned().collect()
    };
    assert_eq!(seen, expected, "the checked set must be exactly registry minus gated");
    // And the cpu family can never be gated: it must always appear.
    for name in seen.iter() {
        assert!(registry.contains(name));
    }
    assert!(
        seen.iter().any(|n| n.starts_with("cpu-")),
        "artifact-free operators must always be exercised"
    );
}

#[test]
fn assembling_operators_fold_dssum_and_mask_bitwise() {
    // The assembly-fused family's registry contract, policed over the
    // *whole* registry (metadata) and exercised on every assembling
    // operator (behavior):
    //
    // * `assembles` is claimable exactly by the `cpu-asm*` names — a
    //   future registration can neither dodge this suite nor trick the
    //   solver into skipping a dssum it still needs;
    // * built with a real-mesh fold plan, each one claims
    //   `applies_assembly()` and reproduces mask(dssum(sweep(u))) —
    //   **bitwise** against the f64 pipeline at the Exact tier, bitwise
    //   against the f32-stored pipeline (and within the reduced band of
    //   the f64 one) at ReducedStorage;
    // * the fused pair reports the already-assembled pap for masked `u`
    //   (every CG iterate is masked);
    // * `bytes_moved()` switches to the assembled stream count — the
    //   separate pass's 2 × ndof re-stream of `w` is gone.
    let n = 4usize;
    let mesh = nekbone::mesh::Mesh::new(2, 2, 1, n).unwrap();
    let basis = nekbone::basis::Basis::new(n);
    let geom = nekbone::geometry::GeomFactors::affine(&mesh, &basis);
    let mask = mesh.boundary_mask();
    let cw = mesh.inv_multiplicity();
    let ndof = mesh.ndof_local();
    let mut gs = nekbone::gs::GatherScatter::new(&mesh);
    let plan = gs.assembly_plan(n * n * n, Some(&mask)).unwrap();
    let mut u = Rng::new(0xA5E4B).normal_vec(ndof);
    nekbone::solver::mask_apply(&mut u, &mask);

    // The two pipeline references: the f64 sweep and the f32-stored sweep
    // (factors rounded once), each followed by the standalone dssum + mask
    // the asm family folds away.
    let mut want = vec![0.0; ndof];
    ax_layered(n, mesh.nelt(), &u, &basis.d, &geom.g, &mut want);
    gs.dssum(&mut want);
    nekbone::solver::mask_apply(&mut want, &mask);
    let g32: Vec<f32> = geom.g.iter().map(|&x| x as f32).collect();
    let mut want32 = vec![0.0; ndof];
    ax_layered_store(n, mesh.nelt(), &u, &basis.d, &g32, &mut want32);
    gs.dssum(&mut want32);
    nekbone::solver::mask_apply(&mut want32, &mask);

    let cx = OperatorCtx {
        n,
        nelt: mesh.nelt(),
        chunk: mesh.nelt(),
        threads: 0,
        artifacts_dir: artifacts_dir(),
        d: &basis.d,
        g: &geom.g,
        c: &cw,
        assemble: Some(&plan),
    };
    let registry = OperatorRegistry::default();
    let mut checked = 0;
    for name in registry.names() {
        let spec = registry.resolve(&name).unwrap();
        assert_eq!(
            spec.assembles,
            name.starts_with("cpu-asm"),
            "{name}: `assembles` metadata must follow the cpu-asm naming contract"
        );
        if !spec.assembles {
            continue;
        }
        let mut op = registry.build(&name, &cx).unwrap();
        assert!(op.applies_assembly(), "{name}: built with a plan, must claim assembly");
        let mut w = vec![123.0; ndof]; // poisoned
        op.apply(&u, &mut w).unwrap();
        match spec.tier {
            PrecisionTier::ReducedStorage => {
                for (i, (&gi, &wi)) in w.iter().zip(&want32).enumerate() {
                    assert_eq!(
                        gi.to_bits(),
                        wi.to_bits(),
                        "{name}[{i}]: must be bitwise the f32-stored sweep+dssum+mask"
                    );
                }
                assert_within_band(&w, &want, REDUCED_BAND, &name);
            }
            tier => {
                assert_eq!(tier, PrecisionTier::Exact, "{name}: f64 asm operators are Exact");
                for (i, (&gi, &wi)) in w.iter().zip(&want).enumerate() {
                    assert_eq!(
                        gi.to_bits(),
                        wi.to_bits(),
                        "{name}[{i}]: must be bitwise layered+dssum+mask ({gi} vs {wi})"
                    );
                }
            }
        }
        if op.is_fused() {
            let pap = op.last_pap().unwrap_or_else(|| {
                panic!("{name}: fused apply must produce a pap")
            });
            let want_pap = glsc3(&w, &cw, &u);
            assert_pap_close(pap, want_pap, &w, &cw, &u, 1e-12, &name);
        } else {
            assert_eq!(op.last_pap(), None, "{name}: unfused asm never reports a pap");
        }
        let stored = if spec.tier == PrecisionTier::ReducedStorage { 4 } else { 8 };
        assert_eq!(
            op.bytes_moved(),
            ax_bytes_moved_assembled(n, mesh.nelt(), op.is_fused(), stored),
            "{name}: assembled mode must drop the separate-pass w re-stream"
        );
        checked += 1;
    }
    assert!(checked >= 4, "registry lost the cpu-asm family (checked only {checked})");
}
