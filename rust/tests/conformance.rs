//! Registry-wide operator conformance suite.
//!
//! Every test here enumerates [`OperatorRegistry::default`] — never a
//! hand-written name list — and subjects **every** registered operator to
//! the shared contract: agreement with `cpu-naive`, the fused-pap
//! promise, Eq. (1) flop/stream accounting, label→resolve round-trips,
//! and a full CG solve. A future registration can therefore never ship
//! without coverage (each earlier suite hand-listed backend names, and
//! adding `cpu-spec` meant retro-editing four files).
//!
//! Coverage is enforced, not assumed: the only legitimate skip is an
//! artifact-backed operator on a host without AOT artifacts, and that
//! exemption comes from the registry's own `needs_artifacts` metadata —
//! an artifact-free operator can never be skipped, and the suite fails if
//! tested + artifact-gated does not equal the whole registry. (When
//! artifacts are present the `xla-*` operators run the same checks; the
//! shapes then must exist in the manifest, which `make artifacts`
//! produces for the configurations used here.)

use std::collections::BTreeSet;

use nekbone::config::RunConfig;
use nekbone::coordinator::Nekbone;
use nekbone::operators::{
    ax_bytes_moved, ax_flops, ax_naive, fused_ax_flops, AxOperator, OperatorCtx,
    OperatorRegistry,
};
use nekbone::proputil::{assert_allclose, assert_pap_close};
use nekbone::rng::Rng;
use nekbone::solver::glsc3;

fn artifacts_dir() -> &'static str {
    concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")
}

fn artifacts_present() -> bool {
    std::path::Path::new(artifacts_dir()).join("manifest.json").exists()
}

/// Run `check(registry, name)` on every canonical operator in the default
/// registry, then assert nothing was skipped: the tested set plus the
/// artifact-gated set must be exactly the registry, and only operators
/// whose spec declares `needs_artifacts` may ever land in the gated set.
fn for_every_operator(mut check: impl FnMut(&OperatorRegistry, &str)) {
    let registry = OperatorRegistry::default();
    let all: BTreeSet<String> = registry.names().into_iter().collect();
    assert!(!all.is_empty(), "default registry is empty");
    let mut tested = BTreeSet::new();
    let mut gated = BTreeSet::new();
    for name in &all {
        let spec = registry.resolve(name).expect("canonical names resolve");
        assert_eq!(&spec.name, name, "resolve must round-trip the canonical name");
        if spec.needs_artifacts && !artifacts_present() {
            gated.insert(name.clone());
            continue;
        }
        check(&registry, name);
        tested.insert(name.clone());
    }
    let covered: BTreeSet<String> = tested.union(&gated).cloned().collect();
    assert_eq!(covered, all, "conformance suite skipped a registered operator");
    for name in &gated {
        assert!(
            registry.resolve(name).unwrap().needs_artifacts,
            "{name} was gated without declaring an artifact requirement"
        );
    }
    assert!(!tested.is_empty(), "conformance suite exercised no operator at all");
}

/// Deterministic inputs for one (n, nelt) case; `c` strictly positive as
/// the inner-product weights are in a real solve.
fn inputs(seed: u64, n: usize, nelt: usize) -> (Vec<f64>, Vec<f64>, Vec<f64>, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let np = n * n * n;
    let u = rng.normal_vec(nelt * np);
    let d = nekbone::basis::derivative_matrix(n);
    let g = rng.normal_vec(nelt * 6 * np);
    let c: Vec<f64> = (0..nelt * np).map(|_| rng.range(0.1, 1.0)).collect();
    (u, d, g, c)
}

fn ctx<'a>(n: usize, nelt: usize, d: &'a [f64], g: &'a [f64], c: &'a [f64]) -> OperatorCtx<'a> {
    OperatorCtx {
        n,
        nelt,
        chunk: nelt,
        threads: 0,
        artifacts_dir: artifacts_dir(),
        d,
        g,
        c,
    }
}

#[test]
fn every_operator_agrees_with_cpu_naive() {
    // Across degrees and element counts, every registered operator's w
    // must match the Listing-1 oracle (`cpu-naive` is itself enumerated
    // and thus compared against the raw kernel it wraps).
    for (case, &(n, nelt)) in [(2usize, 3usize), (3, 2), (5, 3), (10, 2)].iter().enumerate() {
        let (u, d, g, c) = inputs(0xC0F0 + case as u64, n, nelt);
        let np = n * n * n;
        let mut want = vec![0.0; nelt * np];
        ax_naive(n, nelt, &u, &d, &g, &mut want);
        for_every_operator(|registry, name| {
            let mut op = registry.build(name, &ctx(n, nelt, &d, &g, &c)).unwrap();
            let mut w = vec![123.0; nelt * np]; // poisoned
            op.apply(&u, &mut w).unwrap();
            assert_allclose(&w, &want, 1e-11, 1e-11);
        });
    }
}

#[test]
fn fused_operators_honor_the_pap_contract() {
    // `last_pap` is None before the first apply, equals glsc3(w, c, u) of
    // the operator's own output after it (tolerance scaled by the terms'
    // magnitude so cancellation cannot mask a real error), and is
    // bit-reproducible across applies. Unfused operators must report None
    // throughout.
    let (n, nelt) = (4, 3);
    let (u, d, g, c) = inputs(0xC0F1, n, nelt);
    let np = n * n * n;
    for_every_operator(|registry, name| {
        let mut op = registry.build(name, &ctx(n, nelt, &d, &g, &c)).unwrap();
        assert_eq!(op.last_pap(), None, "{name}: pap must be None before the first apply");
        let mut w = vec![0.0; nelt * np];
        op.apply(&u, &mut w).unwrap();
        if op.is_fused() {
            let pap = op.last_pap().unwrap_or_else(|| {
                panic!("{name}: fused apply must produce a pap")
            });
            let want = glsc3(&w, &c, &u);
            assert_pap_close(pap, want, &w, &c, &u, 1e-12, name);
            let mut w2 = vec![0.0; nelt * np];
            op.apply(&u, &mut w2).unwrap();
            assert_eq!(w2, w, "{name}: apply must be deterministic");
            let pap2 = op.last_pap().unwrap();
            assert_eq!(pap.to_bits(), pap2.to_bits(), "{name}: pap must be reproducible");
        } else {
            assert_eq!(op.last_pap(), None, "{name}: unfused operators never report a pap");
        }
    });
}

#[test]
fn flops_and_bytes_follow_eq1_stream_accounting() {
    // The roofline places operators by flops()/bytes_moved(); both hooks
    // must report the Eq. (1) count for the operator's fusion class (and
    // zero before setup, so a blank operator can't fake a placement).
    let (n, nelt) = (5, 3);
    let (_u, d, g, c) = inputs(0xC0F2, n, nelt);
    for_every_operator(|registry, name| {
        let blank = registry.create(name).unwrap();
        assert_eq!(blank.flops(), 0, "{name}: flops before setup");
        assert_eq!(blank.bytes_moved(), 0, "{name}: bytes before setup");
        let op = registry.build(name, &ctx(n, nelt, &d, &g, &c)).unwrap();
        let want_flops =
            if op.is_fused() { fused_ax_flops(n, nelt) } else { ax_flops(n, nelt) };
        assert_eq!(op.flops(), want_flops, "{name}: flops() off the Eq. (1) count");
        let want_bytes = ax_bytes_moved(n, nelt, op.is_fused());
        assert_eq!(op.bytes_moved(), want_bytes, "{name}: bytes_moved() off stream accounting");
    });
}

#[test]
fn labels_round_trip_through_the_registry() {
    // A label printed in any report or bench must parse back to the same
    // operator — before and after setup.
    let (n, nelt) = (3, 2);
    let (_u, d, g, c) = inputs(0xC0F3, n, nelt);
    for_every_operator(|registry, name| {
        let blank = registry.create(name).unwrap();
        assert_eq!(blank.label(), name, "{name}: blank label is not canonical");
        let op = registry.build(name, &ctx(n, nelt, &d, &g, &c)).unwrap();
        assert_eq!(op.label(), name, "{name}: setup changed the label");
        assert_eq!(
            registry.resolve(&op.label()).unwrap().name,
            name,
            "{name}: label does not resolve back"
        );
    });
}

#[test]
fn every_operator_runs_full_cg_to_the_same_residual() {
    // End to end: mesh, dssum, mask, CG. Every registered operator must
    // reproduce the reference residual trajectory (same iteration count is
    // implied by the fixed niter; the residual pins the trajectory).
    let cfg = RunConfig {
        nelt: 8,
        n: 4,
        niter: 30,
        artifacts_dir: artifacts_dir().to_string(),
        ..RunConfig::default()
    };
    let want = Nekbone::builder(cfg.clone())
        .operator("cpu-naive")
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert!(want.final_residual.is_finite());
    for_every_operator(|_registry, name| {
        let mut app = Nekbone::builder(cfg.clone()).operator(name).build().unwrap();
        let got = app.run().unwrap();
        assert_eq!(got.backend, name, "report label must be the registry name");
        assert_eq!(got.iterations, cfg.niter, "{name}: iteration count");
        let denom = want.final_residual.abs().max(1e-30);
        assert!(
            (got.final_residual - want.final_residual).abs() / denom < 1e-9,
            "{name}: residual {} vs reference {}",
            got.final_residual,
            want.final_residual
        );
    });
}

#[test]
fn coverage_cannot_be_dodged_by_an_artifact_free_operator() {
    // The enforcement mechanism itself: an artifact-free operator that a
    // check closure never reaches must fail the suite. Simulated by
    // asserting the gated set is exactly the artifact-backed names when
    // artifacts are absent (and empty when they are present).
    let registry = OperatorRegistry::default();
    let artifact_backed: BTreeSet<String> = registry
        .names()
        .into_iter()
        .filter(|name| registry.resolve(name).unwrap().needs_artifacts)
        .collect();
    let mut seen = BTreeSet::new();
    for_every_operator(|_registry, name| {
        seen.insert(name.to_string());
    });
    let all: BTreeSet<String> = registry.names().into_iter().collect();
    let expected: BTreeSet<String> = if artifacts_present() {
        all
    } else {
        all.difference(&artifact_backed).cloned().collect()
    };
    assert_eq!(seen, expected, "the checked set must be exactly registry minus gated");
    // And the cpu family can never be gated: it must always appear.
    for name in seen.iter() {
        assert!(registry.contains(name));
    }
    assert!(
        seen.iter().any(|n| n.starts_with("cpu-")),
        "artifact-free operators must always be exercised"
    );
}
