//! Integration: every AOT-compiled Ax artifact must agree with the CPU
//! oracle on random inputs, through the real PJRT load/execute path.
//!
//! Requires `make artifacts` (skipped with a message otherwise).

use nekbone::basis::Basis;
use nekbone::operators::ax_layered;
use nekbone::proputil::assert_allclose;
use nekbone::rng::Rng;
use nekbone::runtime::{AxEngine, XlaRuntime};

fn artifacts_dir() -> Option<String> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if std::path::Path::new(dir).join("manifest.json").exists() {
        Some(dir.to_string())
    } else {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        None
    }
}

fn parity_for(variant: &str, n: usize, chunk: usize, nelt: usize) {
    let Some(dir) = artifacts_dir() else { return };
    let rt = XlaRuntime::new(&dir).expect("runtime");
    let basis = Basis::new(n);
    let np = n * n * n;
    let mut rng = Rng::new(0xA11CE + nelt as u64);
    let u = rng.normal_vec(nelt * np);
    let g = rng.normal_vec(nelt * 6 * np);

    let mut engine =
        AxEngine::new(&rt, variant, n, chunk, nelt, &basis.d, &g).expect("engine");
    let mut got = vec![0.0; nelt * np];
    engine.apply(&rt, &u, &mut got).expect("apply");

    let mut want = vec![0.0; nelt * np];
    ax_layered(n, nelt, &u, &basis.d, &g, &mut want);
    assert_allclose(&got, &want, 1e-10, 1e-10);
}

#[test]
fn layered_matches_cpu_exact_chunk() {
    parity_for("layered", 10, 64, 64);
}

#[test]
fn layered_matches_cpu_multi_chunk() {
    parity_for("layered", 10, 64, 128);
}

#[test]
fn layered_matches_cpu_padded_tail() {
    // 100 elements over chunk 64: one full + one padded launch.
    parity_for("layered", 10, 64, 100);
}

#[test]
fn layered_matches_cpu_tiny_mesh() {
    // Whole mesh smaller than one chunk.
    parity_for("layered", 10, 64, 3);
}

#[test]
fn jnp_matches_cpu() {
    parity_for("jnp", 10, 64, 96);
}

#[test]
fn original_matches_cpu() {
    parity_for("original", 10, 64, 96);
}

#[test]
fn shared_matches_cpu() {
    parity_for("shared", 10, 64, 96);
}

#[test]
fn layered_unroll2_matches_cpu() {
    parity_for("layered_unroll2", 10, 64, 96);
}

#[test]
fn layered_other_degrees() {
    // The portability claim (E7): same kernel at degree 7 and 11.
    parity_for("layered", 8, 64, 64);
    parity_for("layered", 12, 64, 64);
}

#[test]
fn vector_engines_match_rust() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = XlaRuntime::new(&dir).expect("runtime");
    let size = 64 * 1000;
    let mut rng = Rng::new(7);
    let a = rng.normal_vec(size);
    let b = rng.normal_vec(size);
    let c = rng.normal_vec(size);

    let glsc3 = nekbone::runtime::VectorEngine::new(&rt, "glsc3", size).unwrap();
    let got = glsc3.glsc3(&rt, &a, &b, &c).unwrap();
    let want = nekbone::solver::glsc3(&a, &b, &c);
    assert!((got - want).abs() < 1e-8 * want.abs().max(1.0), "{got} vs {want}");

    let add2s1 = nekbone::runtime::VectorEngine::new(&rt, "add2s1", size).unwrap();
    let mut a1 = a.clone();
    add2s1.axpy(&rt, &mut a1, &b, 1.5).unwrap();
    let mut a2 = a.clone();
    nekbone::solver::add2s1(&mut a2, &b, 1.5);
    assert_allclose(&a1, &a2, 1e-12, 1e-12);

    let add2s2 = nekbone::runtime::VectorEngine::new(&rt, "add2s2", size).unwrap();
    let mut b1 = a.clone();
    add2s2.axpy(&rt, &mut b1, &b, -0.25).unwrap();
    let mut b2 = a.clone();
    nekbone::solver::add2s2(&mut b2, &b, -0.25);
    assert_allclose(&b1, &b2, 1e-12, 1e-12);
}

#[test]
fn cg_iter_engine_matches_unfused() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = XlaRuntime::new(&dir).expect("runtime");
    let (n, chunk, nelt) = (10, 64, 96);
    let np = n * n * n;
    let basis = Basis::new(n);
    let mut rng = Rng::new(99);
    let p = rng.normal_vec(nelt * np);
    let g = rng.normal_vec(nelt * 6 * np);
    let c = rng.normal_vec(nelt * np);

    let engine = nekbone::runtime::CgIterEngine::new(
        &rt, "layered", n, chunk, nelt, &basis.d, &g, &c,
    )
    .unwrap();
    let mut w = vec![0.0; nelt * np];
    let pap = engine.apply(&rt, &p, &mut w).unwrap();

    let mut w_want = vec![0.0; nelt * np];
    ax_layered(n, nelt, &p, &basis.d, &g, &mut w_want);
    assert_allclose(&w, &w_want, 1e-10, 1e-10);
    let pap_want = nekbone::solver::glsc3(&w_want, &c, &p);
    assert!(
        (pap - pap_want).abs() < 1e-8 * pap_want.abs().max(1.0),
        "{pap} vs {pap_want}"
    );
}
