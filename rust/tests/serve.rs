//! End-to-end serve-layer suite over real loopback TCP: concurrent-client
//! conformance against serial [`SolveSession`] solves (bitwise), explicit
//! backpressure under a saturated shard queue, and the graceful-drain
//! lifecycle.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use nekbone::cli::Args;
use nekbone::config::RunConfig;
use nekbone::coordinator::Nekbone;
use nekbone::json::{parse, Value};
use nekbone::rng::Rng;
use nekbone::serve::{ServeConfig, ServeReport, Server};

/// Boot a server on an OS-assigned loopback port with extra `serve` CLI
/// options; returns (address, stop flag, join handle).
fn start_server(extra: &[&str]) -> (String, Arc<AtomicBool>, JoinHandle<ServeReport>) {
    let mut argv = vec!["serve".to_string(), "--addr".to_string(), "127.0.0.1:0".to_string()];
    argv.extend(extra.iter().map(|s| s.to_string()));
    let cfg = ServeConfig::from_args(&Args::parse(&argv).unwrap()).unwrap();
    let server = Server::bind(&cfg).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let stop = server.stop_flag();
    let handle = std::thread::spawn(move || server.run().unwrap());
    (addr, stop, handle)
}

struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: &str) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        Client { writer: stream, reader }
    }

    fn exchange(&mut self, line: &str) -> Value {
        writeln!(self.writer, "{line}").unwrap();
        self.writer.flush().unwrap();
        let mut resp = String::new();
        assert!(self.reader.read_line(&mut resp).unwrap() > 0, "server closed early");
        parse(resp.trim()).unwrap()
    }
}

fn solve_line(id: u64, op: &str, n: usize, nelt: usize, niter: usize, rhs: &[f64]) -> String {
    let mut m = BTreeMap::new();
    m.insert("op".to_string(), Value::String("solve".into()));
    m.insert("id".to_string(), Value::Number(id as f64));
    m.insert("operator".to_string(), Value::String(op.to_string()));
    m.insert("n".to_string(), Value::Number(n as f64));
    m.insert("nelt".to_string(), Value::Number(nelt as f64));
    m.insert("niter".to_string(), Value::Number(niter as f64));
    m.insert("rhs".to_string(), Value::Array(rhs.iter().map(|&x| Value::Number(x)).collect()));
    Value::Object(m).dump()
}

/// The serve pool's exact build recipe for a request key — the oracle must
/// construct the identical application state.
fn oracle_config(n: usize, nelt: usize, niter: usize) -> RunConfig {
    RunConfig { nelt, n, niter, chunk: nelt.max(1), ..RunConfig::default() }
}

#[test]
fn interleaved_clients_match_serial_sessions_bitwise() {
    // >= 3 distinct (operator, mesh) keys, each solved for several seeds.
    let keys: [(&str, usize, usize); 3] =
        [("cpu-layered", 3, 2), ("cpu-spec", 4, 2), ("cpu-layered", 4, 4)];
    let niter = 8;
    let seeds: [u64; 3] = [11, 12, 13];

    // Serial oracle first: a borrowing SolveSession per key, repeated
    // solves in seed order — the serving path must reproduce every bit.
    let mut expected: BTreeMap<(usize, u64), (f64, Vec<u64>)> = BTreeMap::new();
    for (ki, &(op, n, nelt)) in keys.iter().enumerate() {
        let mut app =
            Nekbone::builder(oracle_config(n, nelt, niter)).operator(op).build().unwrap();
        let ndof = app.mesh().ndof_local();
        let mut session = app.session();
        for &seed in &seeds {
            let rhs = Rng::new(seed).normal_vec(ndof);
            let report = session.solve(&rhs).unwrap();
            let xbits = session.solution().iter().map(|x| x.to_bits()).collect();
            expected.insert((ki, seed), (report.final_rnorm, xbits));
        }
    }
    let expected = Arc::new(expected);

    let (addr, stop, server) = start_server(&["--shards", "2", "--queue", "16"]);
    // >= 4 client threads, each interleaving all keys and seeds, so
    // different meshes' requests overlap arbitrarily on the wire. Every
    // client must see identical (serial-quality) answers.
    let mut clients = Vec::new();
    for c in 0..4u64 {
        let addr = addr.clone();
        let expected = Arc::clone(&expected);
        clients.push(std::thread::spawn(move || {
            let mut conn = Client::connect(&addr);
            for round in 0..seeds.len() {
                for (ki, &(op, n, nelt)) in keys.iter().enumerate() {
                    // Stagger the order per client so key traffic truly
                    // interleaves instead of marching in lockstep.
                    let seed = seeds[(round + c as usize + ki) % seeds.len()];
                    let rhs = Rng::new(seed).normal_vec(nelt * n * n * n);
                    let id = c * 1000 + (ki * 10 + round) as u64;
                    let v = conn.exchange(&solve_line(id, op, n, nelt, niter, &rhs));
                    assert_eq!(v.get("ok"), Some(&Value::Bool(true)), "{op} n{n} e{nelt}");
                    assert_eq!(v.get("id").unwrap().as_u64(), Some(id));
                    assert_eq!(v.get("operator").unwrap().as_str(), Some(op));
                    let (want_rnorm, want_bits) = &expected[&(ki, seed)];
                    let rnorm = v.get("rnorm").unwrap().as_f64().unwrap();
                    assert_eq!(rnorm.to_bits(), want_rnorm.to_bits(), "{op} n{n} e{nelt}");
                    let x = v.get("x").unwrap().as_array().unwrap();
                    assert_eq!(x.len(), want_bits.len());
                    for (got, want) in x.iter().zip(want_bits.iter()) {
                        assert_eq!(got.as_f64().unwrap().to_bits(), *want);
                    }
                }
            }
        }));
    }
    for c in clients {
        c.join().unwrap();
    }

    stop.store(true, Ordering::SeqCst);
    let report = server.join().unwrap();
    assert_eq!(report.connections, 4);
    // Sessions were cached per key: exactly one warm-up per distinct key.
    let misses: u64 = report.shards.iter().map(|s| s.cache_misses).sum();
    let hits: u64 = report.shards.iter().map(|s| s.cache_hits).sum();
    assert_eq!(misses, keys.len() as u64);
    assert_eq!(hits + misses, (4 * keys.len() * seeds.len()) as u64);
}

#[test]
fn saturated_shard_answers_overloaded_not_buffering() {
    // One shard with a one-slot queue and deliberately heavy solves: a
    // burst from 6 concurrent clients cannot all fit, and the ones that
    // don't must be told so immediately — never queued without bound.
    let (addr, stop, server) =
        start_server(&["--shards", "1", "--queue", "1", "--batch", "1"]);
    let (op, n, nelt, niter) = ("cpu-layered", 6, 4, 300);
    let mut conns: Vec<Client> = (0..6).map(|_| Client::connect(&addr)).collect();
    let results: Vec<String> = std::thread::scope(|scope| {
        let handles: Vec<_> = conns
            .iter_mut()
            .enumerate()
            .map(|(i, conn)| {
                scope.spawn(move || {
                    let rhs = Rng::new(i as u64).normal_vec(nelt * n * n * n);
                    let v = conn.exchange(&solve_line(i as u64, op, n, nelt, niter, &rhs));
                    match v.get("ok") {
                        Some(Value::Bool(true)) => "ok".to_string(),
                        _ => v.get("error").unwrap().as_str().unwrap().to_string(),
                    }
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let ok = results.iter().filter(|r| *r == "ok").count();
    let overloaded = results.iter().filter(|r| *r == "overloaded").count();
    assert!(ok >= 1, "at least the head of the burst solves: {results:?}");
    assert!(overloaded >= 1, "a full one-slot queue must refuse: {results:?}");
    assert_eq!(ok + overloaded, 6, "only ok/overloaded are acceptable: {results:?}");

    stop.store(true, Ordering::SeqCst);
    let report = server.join().unwrap();
    assert!(report.shards[0].overloaded >= overloaded as u64);
    // The depth gauge may transiently count the job a worker has popped
    // but not yet marked served, so the bound is capacity + 1.
    assert!(report.shards[0].max_depth <= 2, "queue depth must respect its bound");
}

#[test]
fn shutdown_request_drains_and_refuses_new_work() {
    let (addr, _stop, server) = start_server(&["--shards", "1", "--queue", "8"]);
    let (op, n, nelt, niter) = ("cpu-layered", 3, 2, 6);
    let rhs = Rng::new(7).normal_vec(nelt * n * n * n);

    // A working connection, answered before the drain begins.
    let mut worker = Client::connect(&addr);
    let v = worker.exchange(&solve_line(1, op, n, nelt, niter, &rhs));
    assert_eq!(v.get("ok"), Some(&Value::Bool(true)));

    // A second connection asks the server to shut down…
    let mut controller = Client::connect(&addr);
    let ack = controller.exchange(r#"{"op":"shutdown","id":2}"#);
    assert_eq!(ack.get("draining"), Some(&Value::Bool(true)));

    // …after which the still-open first connection is refused new work:
    // either an explicit shutting_down error, or — if its idle handler
    // noticed the stop flag first — a prompt close. Never a hang, never a
    // silently accepted solve.
    let _ = writeln!(worker.writer, "{}", solve_line(3, op, n, nelt, niter, &rhs));
    let _ = worker.writer.flush();
    let mut resp = String::new();
    let nread = worker.reader.read_line(&mut resp).unwrap_or(0);
    if nread > 0 {
        let v = parse(resp.trim()).unwrap();
        assert_eq!(v.get("ok"), Some(&Value::Bool(false)), "{resp}");
        assert_eq!(v.get("error").unwrap().as_str(), Some("shutting_down"));
    }

    // And the server itself exits cleanly, reporting both connections.
    let report = server.join().unwrap();
    assert_eq!(report.connections, 2);
    assert_eq!(report.shards.iter().map(|s| s.requests).sum::<u64>(), 1);
}

#[test]
fn protocol_misuse_gets_structured_errors_and_the_connection_survives() {
    let (addr, stop, server) = start_server(&[]);
    let mut conn = Client::connect(&addr);

    let v = conn.exchange("this is not json");
    assert_eq!(v.get("ok"), Some(&Value::Bool(false)));
    assert_eq!(v.get("error").unwrap().as_str(), Some("bad_request"));

    let v = conn.exchange(r#"{"op":"solve","id":8,"operator":"no-such","n":3,"nelt":2,"rhs":[]}"#);
    assert_eq!(v.get("error").unwrap().as_str(), Some("bad_request"));
    assert!(v.get("detail").unwrap().as_str().unwrap().contains("no-such"));

    // Mis-sized rhs names both counts (the session-boundary contract,
    // surfaced through the wire).
    let v = conn.exchange(r#"{"op":"solve","id":9,"operator":"cpu-layered","n":3,"nelt":2,"rhs":[1,2]}"#);
    assert_eq!(v.get("error").unwrap().as_str(), Some("bad_request"));
    let detail = v.get("detail").unwrap().as_str().unwrap().to_string();
    assert!(detail.contains('2') && detail.contains("54"), "{detail}");

    // The same connection still works after every refusal.
    let v = conn.exchange(r#"{"op":"ping","id":10}"#);
    assert_eq!(v.get("pong"), Some(&Value::Bool(true)));
    let v = conn.exchange(r#"{"op":"info","id":11}"#);
    assert!(v.get("operators").unwrap().as_array().unwrap().len() >= 10);

    stop.store(true, Ordering::SeqCst);
    server.join().unwrap();
}
