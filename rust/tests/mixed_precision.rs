//! Deterministic randomized sweep of the reduced-storage (`-f32`) family
//! against the f64 ladder, across every monomorphized degree, element
//! count, and thread count.
//!
//! Accuracy contract under test (the mixed-precision design): the six
//! geometric factors round to **f32 once at setup**, every kernel widens
//! them back per element and **accumulates in f64**. Two consequences are
//! checked exhaustively here:
//!
//! 1. *Band agreement*: each f32 operator matches the f64 layered
//!    reference within `1e-5 · (|want| + max|want|)` per point — the
//!    storage-rounding band with ~10× headroom, tight enough that an
//!    accidental f32 accumulation fails by orders of magnitude.
//! 2. *Pre-rounding equivalence*: feeding the f64 kernels factors that
//!    took a round trip through f32 (`f64(f32(g))`) reproduces the f32
//!    path **bitwise** — the only difference reduced storage makes is the
//!    one rounding, never the schedule.
//!
//! Everything is seeded through `rng::Rng`, so a failure reproduces
//! exactly.

use nekbone::operators::{
    ax_layered, ax_layered_store, ax_simd_f32, ax_simd_fused_f32, ax_simd_fused_f32_with_arm,
    ax_simd_f32_with_arm, OperatorCtx, OperatorRegistry, SimdArm,
};
use nekbone::proputil::assert_pap_close;
use nekbone::solver::glsc3;

mod util;
use crate::util::{inputs, REDUCED_BAND};

fn ctx<'a>(
    n: usize,
    nelt: usize,
    threads: usize,
    d: &'a [f64],
    g: &'a [f64],
    c: &'a [f64],
) -> OperatorCtx<'a> {
    util::ctx(n, nelt, threads, "artifacts", d, g, c)
}

/// The reduced-storage band: per point `1e-5 * (|want| + max|want|)`.
fn assert_within_band(got: &[f64], want: &[f64], what: &str) {
    util::assert_within_band(got, want, REDUCED_BAND, what)
}

#[test]
fn f32_family_sweep_against_layered() {
    // N = 2..=12 (every monomorphized degree) × element counts × thread
    // counts: every registered f32 operator against the f64 layered
    // reference (band) and against its own single-thread f32 kernel
    // (bitwise — threading partitions elements, it never reassociates a
    // point).
    let registry = OperatorRegistry::with_builtins();
    for n in 2..=12usize {
        for &nelt in &[1usize, 3, 5] {
            for &threads in &[1usize, 2, 3] {
                let seed = 0xF32_0000 + (n as u64) * 64 + (nelt as u64) * 8 + threads as u64;
                let (u, d, g, c) = inputs(seed, n, nelt);
                let np = n * n * n;
                let what = format!("n={n} nelt={nelt} threads={threads}");

                let mut w_ref = vec![0.0; nelt * np];
                ax_layered(n, nelt, &u, &d, &g, &mut w_ref);
                let g32: Vec<f32> = g.iter().map(|&x| x as f32).collect();
                // Single-thread f32 references for the bitwise checks.
                let mut w_store = vec![0.0; nelt * np];
                ax_layered_store(n, nelt, &u, &d, &g32, &mut w_store);
                assert_within_band(&w_store, &w_ref, &what);
                let mut w_simd32 = vec![0.0; nelt * np];
                ax_simd_f32(n, nelt, &u, &d, &g32, &mut w_simd32);
                assert_within_band(&w_simd32, &w_ref, &what);

                let cx = ctx(n, nelt, threads, &d, &g, &c);
                for name in ["cpu-layered-f32", "cpu-spec-f32"] {
                    let mut op = registry.build(name, &cx).unwrap();
                    let mut w = vec![123.0; nelt * np]; // poisoned
                    op.apply(&u, &mut w).unwrap();
                    assert_eq!(w, w_store, "{name} {what}: must match the layered store");
                }
                for name in ["cpu-simd-f32", "cpu-threaded-f32"] {
                    let mut op = registry.build(name, &cx).unwrap();
                    let mut w = vec![123.0; nelt * np];
                    op.apply(&u, &mut w).unwrap();
                    assert_eq!(w, w_simd32, "{name} {what}: must match single-thread simd");
                }
                for name in ["cpu-layered-fused-f32", "cpu-spec-fused-f32"] {
                    let mut op = registry.build(name, &cx).unwrap();
                    let mut w = vec![123.0; nelt * np];
                    op.apply(&u, &mut w).unwrap();
                    assert_eq!(w, w_store, "{name} {what}: fused w must match unfused");
                    let pap = op.last_pap().expect("fused apply must produce pap");
                    let want = glsc3(&w, &c, &u);
                    assert_pap_close(pap, want, &w, &c, &u, 1e-12, &format!("{name} {what}"));
                }
                for name in ["cpu-simd-fused-f32", "cpu-threaded-fused-f32"] {
                    let mut op = registry.build(name, &cx).unwrap();
                    let mut w = vec![123.0; nelt * np];
                    op.apply(&u, &mut w).unwrap();
                    assert_eq!(w, w_simd32, "{name} {what}: fused w must match unfused simd");
                    let pap = op.last_pap().expect("fused apply must produce pap");
                    let want = glsc3(&w, &c, &u);
                    assert_pap_close(pap, want, &w, &c, &u, 1e-12, &format!("{name} {what}"));
                }
            }
        }
    }
}

#[test]
fn f32_path_equals_f64_path_on_prerounded_factors_bitwise() {
    // The design's sharpest invariant: reduced storage differs from f64
    // storage by exactly one rounding of the factors. Feed the f64
    // kernels `f64(f32(g))` and the f32 kernels `f32(g)` — identical
    // output bits, on the forced-scalar arm and on whatever arm this
    // host dispatches, fused and unfused alike.
    for n in (2..=13usize).chain([16]) {
        let nelt = 3;
        let (u, d, g, c) = inputs(0xF32_BB + n as u64, n, nelt);
        let np = n * n * n;
        let g32: Vec<f32> = g.iter().map(|&x| x as f32).collect();
        let g_rounded: Vec<f64> = g32.iter().map(|&x| x as f64).collect();

        let mut want = vec![0.0; nelt * np];
        ax_layered(n, nelt, &u, &d, &g_rounded, &mut want);
        let mut got = vec![123.0; nelt * np];
        ax_layered_store(n, nelt, &u, &d, &g32, &mut got);
        assert_eq!(got, want, "n={n}: layered store vs pre-rounded layered");

        let mut w_s = vec![123.0; nelt * np];
        ax_simd_f32_with_arm(SimdArm::Scalar, n, nelt, &u, &d, &g32, &mut w_s);
        assert_eq!(w_s, want, "n={n}: forced-scalar simd-f32 vs pre-rounded layered");

        // Dispatched arm: f32 vs f64-on-pre-rounded through the *same*
        // arm — FMA reassociation cancels out, the rounding is all.
        let mut w_a = vec![123.0; nelt * np];
        ax_simd_f32(n, nelt, &u, &d, &g32, &mut w_a);
        let mut w_b = vec![123.0; nelt * np];
        nekbone::operators::ax_simd(n, nelt, &u, &d, &g_rounded, &mut w_b);
        assert_eq!(w_a, w_b, "n={n}: dispatched simd-f32 vs pre-rounded simd");

        let mut wf_a = vec![123.0; nelt * np];
        let pap_a = ax_simd_fused_f32(n, nelt, &u, &d, &g32, &c, &mut wf_a);
        let mut wf_b = vec![123.0; nelt * np];
        let pap_b =
            nekbone::operators::ax_simd_fused(n, nelt, &u, &d, &g_rounded, &c, &mut wf_b);
        assert_eq!(wf_a, wf_b, "n={n}: dispatched fused simd-f32 vs pre-rounded");
        assert_eq!(pap_a.to_bits(), pap_b.to_bits(), "n={n}: fused pap bits");

        let mut wf_s = vec![123.0; nelt * np];
        let pap_s =
            ax_simd_fused_f32_with_arm(SimdArm::Scalar, n, nelt, &u, &d, &g32, &c, &mut wf_s);
        assert_eq!(wf_s, want, "n={n}: forced-scalar fused-f32 w");
        let mut wf_l = vec![123.0; nelt * np];
        let pap_l = nekbone::operators::ax_layered_fused(
            n, nelt, &u, &d, &g_rounded, &c, &mut wf_l,
        );
        assert_eq!(pap_s.to_bits(), pap_l.to_bits(), "n={n}: forced-scalar fused pap bits");
    }
}

#[test]
fn f32_operators_move_fewer_bytes_for_the_same_flops() {
    // The point of the exercise, visible in the registry metadata: every
    // f32 operator reports the same Eq. (1) flop count as its f64
    // sibling but strictly less stream traffic — i.e. strictly higher
    // arithmetic intensity on the roofline.
    let registry = OperatorRegistry::with_builtins();
    let (n, nelt) = (5, 3);
    let (_u, d, g, c) = inputs(0xF32_CC, n, nelt);
    let cx = ctx(n, nelt, 0, &d, &g, &c);
    for (f32_name, f64_name) in [
        ("cpu-layered-f32", "cpu-layered"),
        ("cpu-spec-f32", "cpu-spec"),
        ("cpu-simd-f32", "cpu-simd"),
        ("cpu-threaded-f32", "cpu-threaded"),
        ("cpu-layered-fused-f32", "cpu-layered-fused"),
        ("cpu-spec-fused-f32", "cpu-spec-fused"),
        ("cpu-simd-fused-f32", "cpu-simd-fused"),
        ("cpu-threaded-fused-f32", "cpu-threaded-fused"),
    ] {
        let a = registry.build(f32_name, &cx).unwrap();
        let b = registry.build(f64_name, &cx).unwrap();
        assert_eq!(a.flops(), b.flops(), "{f32_name}: flops must not change");
        assert!(
            a.bytes_moved() < b.bytes_moved(),
            "{f32_name}: must move fewer bytes than {f64_name} ({} vs {})",
            a.bytes_moved(),
            b.bytes_moved()
        );
    }
}
