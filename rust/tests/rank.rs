//! Cross-decomposition conformance: slab, pencil, and box ranked solves
//! against the serial pipeline.
//!
//! The rank runtime's contract is strict: per-rank reports are **bitwise
//! identical to the serial solve** for every decomposition shape (see the
//! three mechanisms in `rank/mod.rs`'s module docs — element-blocked
//! ordered reductions, ascending-element local assembly, and raw-copy
//! refolds of cross-rank boundary points). These tests hold the public
//! entry points to that contract across a shape × ranks × degree grid,
//! check the decomposition's shared-point sets against the analytic
//! cut-plane formula, and pin the fused-pap correction on multi-neighbor
//! (pencil/box) topologies.

use std::collections::BTreeSet;

use nekbone::config::RunConfig;
use nekbone::coordinator::{Nekbone, RunReport};
use nekbone::mesh::Mesh;
use nekbone::rank::{run_ranked_with, DecompShape, Decomposition};

/// The conformance grid: every shape at two rank counts that divide the
/// 2×2×2 element grid of `nelt = 8`.
const GRID: &[(&str, usize)] = &[
    ("slab", 1),
    ("slab", 2),
    ("pencil", 2),
    ("pencil", 4),
    ("box", 4),
    ("box", 8),
];

fn serial_report(cfg: &RunConfig) -> RunReport {
    let serial = RunConfig { ranks: 1, decomp: "slab".into(), ..cfg.clone() };
    let mut app = Nekbone::builder(serial).operator("cpu-layered").build().unwrap();
    app.run().unwrap()
}

fn assert_bitwise(got: &RunReport, want: &RunReport, tag: &str) {
    assert_eq!(got.iterations, want.iterations, "{tag}: iteration counts");
    assert_eq!(
        got.final_residual.to_bits(),
        want.final_residual.to_bits(),
        "{tag}: final residual {} vs serial {}",
        got.final_residual,
        want.final_residual
    );
    assert_eq!(got.rnorms.len(), want.rnorms.len(), "{tag}: history length");
    for (i, (a, b)) in got.rnorms.iter().zip(&want.rnorms).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{tag} iter {i}: {a} vs serial {b}");
    }
}

#[test]
fn every_shape_reproduces_the_serial_solve_bitwise() {
    for &n in &[3usize, 4] {
        let base = RunConfig {
            nelt: 8,
            n,
            niter: 15,
            record_residuals: true,
            ..Default::default()
        };
        let want = serial_report(&base);
        assert_eq!(want.rnorms.len(), want.iterations, "serial records every iteration");
        for &(shape, ranks) in GRID {
            let cfg = RunConfig { ranks, decomp: shape.into(), ..base.clone() };
            let got = run_ranked_with(&cfg, "cpu-layered").unwrap();
            assert!(
                got.backend.ends_with(&format!("-r{ranks}-{shape}")),
                "backend label must carry the shape: {}",
                got.backend
            );
            assert_bitwise(&got, &want, &format!("{shape}/r{ranks}/n{n}"));
        }
    }
}

#[test]
fn larger_mesh_stays_bitwise_across_shapes() {
    // 64 elements (4×4×4): bricks are genuinely non-contiguous in the
    // full-mesh arrays for pencil/box, and box ranks see edge + corner
    // neighbor links — the exchange paths a 2×2×2 grid cannot reach.
    let base =
        RunConfig { nelt: 64, n: 3, niter: 12, record_residuals: true, ..Default::default() };
    let want = serial_report(&base);
    for (shape, ranks) in [("slab", 4), ("pencil", 4), ("box", 8)] {
        let cfg = RunConfig { ranks, decomp: shape.into(), ..base.clone() };
        let got = run_ranked_with(&cfg, "cpu-layered").unwrap();
        assert_bitwise(&got, &want, &format!("{shape}/r{ranks}/nelt64"));
    }
}

#[test]
fn shared_point_counts_match_the_cut_plane_formula() {
    // Every point two ranks share lies on an internal cut plane, and the
    // union over the plane families is inclusion–exclusion over the
    // |C_axis| = p_axis − 1 cuts. Holding the decomposition's link gid
    // sets to the analytic count pins both the neighbor enumeration and
    // the per-link gid lists (no point missed, none double-owned).
    let mesh = Mesh::for_nelt(64, 4).unwrap();
    for &(shape_s, ranks) in GRID {
        let shape = DecompShape::parse(shape_s).unwrap();
        let d = Decomposition::new(shape, ranks, &mesh).unwrap();
        let mut union: BTreeSet<usize> = BTreeSet::new();
        for r in 0..ranks {
            for (_, gids) in d.neighbors(r) {
                union.extend(gids.iter().copied());
            }
        }
        let (gx, gy, gz) = (mesh.gx, mesh.gy, mesh.gz);
        let (cx, cy, cz) = (d.px - 1, d.py - 1, d.pz - 1);
        let want = cz * gx * gy + cy * gx * gz + cx * gy * gz
            - (cy * cz * gx + cx * cz * gy + cx * cy * gz)
            + cx * cy * cz;
        assert_eq!(
            union.len(),
            want,
            "{shape_s}/r{ranks} (px={} py={} pz={})",
            d.px,
            d.py,
            d.pz
        );
    }
}

#[test]
fn fused_pap_correction_holds_on_multi_neighbor_topologies() {
    // The fused operators compute pap inside Ax and patch it over the
    // exchange's shared dofs. On pencil/box decompositions that support
    // includes face, edge, and corner links — the correction must track
    // the unfused trajectory (same iterations, residual to round-off)
    // there too, not just on the two-neighbor slab chain.
    for (shape, ranks) in [("pencil", 4), ("box", 8)] {
        let base = RunConfig {
            nelt: 8,
            n: 4,
            niter: 20,
            ranks,
            decomp: shape.into(),
            ..Default::default()
        };
        let want = run_ranked_with(&base, "cpu-layered").unwrap();
        for name in ["cpu-layered-fused", "cpu-threaded-fused"] {
            let got = run_ranked_with(&base, name).unwrap();
            assert_eq!(got.iterations, want.iterations, "{shape}/{name}");
            let denom = want.final_residual.abs().max(1e-30);
            assert!(
                (got.final_residual - want.final_residual).abs() / denom < 1e-9,
                "{shape}/{name}: {} vs {}",
                got.final_residual,
                want.final_residual
            );
        }
    }
}
