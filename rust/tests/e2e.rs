//! End-to-end integration: full Nekbone solves across operators, ranked vs
//! serial, the paper's no-comm roofline mode, and a runtime-registered
//! custom operator through the builder + registry API.

use nekbone::config::RunConfig;
use nekbone::coordinator::{Nekbone, VectorBackend};
use nekbone::operators::{ax_layered, AxOperator, OperatorCtx, OperatorRegistry};
use nekbone::rank::run_ranked;

fn have_artifacts() -> bool {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let ok = std::path::Path::new(dir).join("manifest.json").exists();
    if !ok {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
    }
    ok
}

fn cfg(nelt: usize, n: usize, niter: usize) -> RunConfig {
    RunConfig { nelt, n, niter, ..Default::default() }
}

fn app(operator: &str, cfg: RunConfig) -> Nekbone {
    Nekbone::builder(cfg).operator(operator).build().expect("operator setup")
}

#[test]
fn xla_backends_match_cpu_end_to_end() {
    if !have_artifacts() {
        return;
    }
    // Full CG: identical residual trajectory on CPU and through PJRT.
    let mut cpu = app("cpu-layered", cfg(64, 10, 15));
    let want = cpu.run().unwrap();
    for operator in
        ["xla-jnp", "xla-original", "xla-shared", "xla-layered", "xla-layered-unroll2"]
    {
        let mut xla = app(operator, cfg(64, 10, 15));
        let got = xla.run().unwrap();
        let denom = want.final_residual.abs().max(1e-30);
        assert!(
            (got.final_residual - want.final_residual).abs() / denom < 1e-9,
            "{operator}: {} vs {}",
            got.final_residual,
            want.final_residual
        );
    }
}

#[test]
fn xla_padded_mesh_matches_cpu() {
    if !have_artifacts() {
        return;
    }
    // nelt = 100 is not a multiple of the chunk: exercises zero-padding
    // through a complete solve (dssum + mask + CG).
    let mut cpu = app("cpu-layered", cfg(100, 10, 10));
    let want = cpu.run().unwrap();
    let mut xla = app("xla-layered", cfg(100, 10, 10));
    let got = xla.run().unwrap();
    let denom = want.final_residual.abs().max(1e-30);
    assert!((got.final_residual - want.final_residual).abs() / denom < 1e-9);
}

#[test]
fn fused_backend_matches_unfused() {
    if !have_artifacts() {
        return;
    }
    let mut plain = app("xla-layered", cfg(64, 10, 12));
    let want = plain.run().unwrap();
    // Through the alias: "xla-fused" resolves to "xla-fused-layered".
    let mut fused = app("xla-fused", cfg(64, 10, 12));
    let got = fused.run().unwrap();
    assert_eq!(got.backend, "xla-fused-layered", "fused label must be canonical");
    let denom = want.final_residual.abs().max(1e-30);
    assert!(
        (got.final_residual - want.final_residual).abs() / denom < 1e-9,
        "fused {} vs {}",
        got.final_residual,
        want.final_residual
    );
}

#[test]
fn fused_no_comm_uses_fused_pap() {
    if !have_artifacts() {
        return;
    }
    // In no-comm, no-mask mode the fused pap is used directly; it must
    // still agree with the plain path.
    let mk = || RunConfig { no_comm: true, no_mask: true, ..cfg(64, 10, 8) };
    let mut plain = app("xla-layered", mk());
    let want = plain.run().unwrap();
    let mut fused = app("xla-fused-layered", mk());
    let got = fused.run().unwrap();
    let denom = want.final_residual.abs().max(1e-30);
    assert!((got.final_residual - want.final_residual).abs() / denom < 1e-9);
}

#[test]
fn cpu_fused_backends_match_unfused_end_to_end() {
    // No artifacts needed: the CPU fused hot path (cpu-layered-fused
    // single thread, cpu-threaded-fused on the persistent worker pool)
    // must reproduce the unfused residual through a full solve — dssum,
    // mask, CG — and report its canonical label.
    let mut plain = app("cpu-layered", cfg(27, 5, 20));
    let mut x_plain = vec![0.0; plain.mesh().ndof_local()];
    let want = plain.run_into(Some(&mut x_plain)).unwrap();
    for operator in ["cpu-layered-fused", "cpu-threaded-fused"] {
        let mut fused = app(operator, cfg(27, 5, 20));
        let mut x_fused = vec![0.0; fused.mesh().ndof_local()];
        let got = fused.run_into(Some(&mut x_fused)).unwrap();
        assert_eq!(got.backend, operator, "fused label must be canonical");
        assert_eq!(got.iterations, want.iterations);
        let denom = want.final_residual.abs().max(1e-30);
        assert!(
            (got.final_residual - want.final_residual).abs() / denom < 1e-9,
            "{operator}: {} vs {}",
            got.final_residual,
            want.final_residual
        );
        nekbone::proputil::assert_allclose(&x_fused, &x_plain, 1e-9, 1e-11);
    }
}

#[test]
fn cpu_fused_backends_match_unfused_ranked() {
    // The fused operators drop into the simulated-MPI runtime too.
    let base = RunConfig { nelt: 27, n: 4, niter: 15, ranks: 3, ..Default::default() };
    let want = nekbone::rank::run_ranked_with(&base, "cpu-layered").unwrap();
    let got = nekbone::rank::run_ranked_with(&base, "cpu-threaded-fused").unwrap();
    assert!(got.backend.contains("cpu-threaded-fused"), "{}", got.backend);
    let denom = want.final_residual.abs().max(1e-30);
    assert!(
        (got.final_residual - want.final_residual).abs() / denom < 1e-9,
        "{} vs {}",
        got.final_residual,
        want.final_residual
    );
}

#[test]
fn vector_backend_xla_matches_rust() {
    if !have_artifacts() {
        return;
    }
    let mut rust_vec = app("xla-layered", cfg(64, 10, 10));
    let want = rust_vec.run().unwrap();
    let mut xla_vec = Nekbone::builder(cfg(64, 10, 10))
        .operator("xla-layered")
        .vector_backend(VectorBackend::Xla)
        .build()
        .unwrap();
    let got = xla_vec.run().unwrap();
    let denom = want.final_residual.abs().max(1e-30);
    assert!(
        (got.final_residual - want.final_residual).abs() / denom < 1e-8,
        "{} vs {}",
        got.final_residual,
        want.final_residual
    );
}

#[test]
fn ranked_matches_serial_on_larger_mesh() {
    let base = RunConfig { nelt: 27, n: 5, niter: 20, ..Default::default() };
    let mut serial = app("cpu-layered", base.clone());
    let want = serial.run().unwrap();
    for ranks in [1, 3] {
        let got = run_ranked(&RunConfig { ranks, ..base.clone() }).unwrap();
        let denom = want.final_residual.abs().max(1e-30);
        assert!(
            (got.final_residual - want.final_residual).abs() / denom < 1e-6,
            "ranks={ranks}: {} vs {}",
            got.final_residual,
            want.final_residual
        );
    }
}

#[test]
fn chunk_256_matches_chunk_64() {
    if !have_artifacts() {
        return;
    }
    let c64 = cfg(256, 10, 8);
    let c256 = RunConfig { chunk: 256, ..cfg(256, 10, 8) };
    let mut a = app("xla-layered", c64);
    let mut b = app("xla-layered", c256);
    let ra = a.run().unwrap();
    let rb = b.run().unwrap();
    let denom = ra.final_residual.abs().max(1e-30);
    assert!((ra.final_residual - rb.final_residual).abs() / denom < 1e-9);
}

/// A third-party operator: wraps the layered kernel. Registered at runtime
/// under a new name and driven through the full application (mesh, dssum,
/// mask, CG) — no artifacts, no enum variants.
#[derive(Default)]
struct CountingLayered {
    st: Option<(usize, usize, Vec<f64>, Vec<f64>)>,
}

impl AxOperator for CountingLayered {
    fn label(&self) -> String {
        "test-counting-layered".into()
    }

    fn setup(&mut self, ctx: &OperatorCtx) -> nekbone::Result<()> {
        self.st = Some((ctx.n, ctx.nelt, ctx.d.to_vec(), ctx.g.to_vec()));
        Ok(())
    }

    fn apply(&mut self, u: &[f64], w: &mut [f64]) -> nekbone::Result<()> {
        let (n, nelt, d, g) = self.st.as_ref().expect("setup ran");
        ax_layered(*n, *nelt, u, d, g, w);
        Ok(())
    }

    fn flops(&self) -> u64 {
        self.st
            .as_ref()
            .map_or(0, |(n, nelt, _, _)| nekbone::operators::ax_flops(*n, *nelt))
    }
}

#[test]
fn runtime_registered_operator_runs_full_cg() {
    // The acceptance path for the registry API: register a custom operator
    // at runtime, build the application by name, run a full CG solve, and
    // match the builtin it wraps.
    let mut registry = OperatorRegistry::with_builtins();
    registry
        .register("test-counting-layered", false, || Box::<CountingLayered>::default())
        .unwrap();

    let run_cfg = cfg(8, 5, 25);
    let mut custom = Nekbone::builder(run_cfg.clone())
        .registry(registry)
        .operator("test-counting-layered")
        .build()
        .unwrap();
    let got = custom.run().unwrap();
    assert_eq!(got.backend, "test-counting-layered");
    assert_eq!(got.iterations, 25);

    let mut builtin = app("cpu-layered", run_cfg);
    let want = builtin.run().unwrap();
    let denom = want.final_residual.abs().max(1e-30);
    assert!(
        (got.final_residual - want.final_residual).abs() / denom < 1e-12,
        "custom {} vs builtin {}",
        got.final_residual,
        want.final_residual
    );
}

#[test]
fn session_solve_batch_matches_independent_solves() {
    // Acceptance for the SolveSession API: a batch of right-hand sides
    // through one session — operator state and CG workspace reused — must
    // reproduce N fresh, independent applications exactly. Run with a
    // fused operator so a stale `last_pap` leaking between batch entries
    // would be caught (each entry must restart the trajectory from x = 0).
    let run_cfg = cfg(27, 5, 18);
    let mut app_session = app("cpu-threaded-fused", run_cfg.clone());
    let ndof = app_session.mesh().ndof_local();
    let rhss: Vec<Vec<f64>> = (0..3)
        .map(|i| nekbone::rng::Rng::new(nekbone::rng::rhs_seed(100, i as u64)).normal_vec(ndof))
        .collect();

    let mut session = app_session.session();
    let reports = session.solve_batch(&rhss).unwrap();
    assert_eq!(reports.len(), rhss.len());
    assert_eq!(session.solves(), rhss.len());

    for (i, (rhs, rep)) in rhss.iter().zip(&reports).enumerate() {
        let mut fresh = app("cpu-threaded-fused", run_cfg.clone());
        fresh.set_rhs(rhs).unwrap();
        let want = fresh.run().unwrap();
        assert_eq!(rep.iterations, want.iterations, "batch entry {i}");
        assert_eq!(
            rep.final_rnorm, want.final_residual,
            "batch entry {i}: session trajectory must be identical to an \
             independent solve (stale fused state between entries?)"
        );
        assert!(rep.final_rnorm.is_finite());
    }
    // Same sweep accounting for every entry: the fused path's
    // one-sweep-per-iteration saving holds across the whole batch.
    for r in &reports[1..] {
        assert_eq!(r.glsc3_sweeps, reports[0].glsc3_sweeps);
    }

    // Per-entry solutions via solve_into agree with independent solves.
    let mut x_session = vec![0.0; ndof];
    let mut x_fresh = vec![0.0; ndof];
    session.solve_into(&rhss[1], &mut x_session).unwrap();
    let mut fresh = app("cpu-threaded-fused", run_cfg);
    fresh.set_rhs(&rhss[1]).unwrap();
    fresh.run_into(Some(&mut x_fresh)).unwrap();
    nekbone::proputil::assert_allclose(&x_session, &x_fresh, 1e-15, 1e-15);
}

#[test]
fn custom_registry_does_not_leak_into_builtins() {
    // Registration is per-registry: the builtin set never sees test names.
    let mut registry = OperatorRegistry::with_builtins();
    registry
        .register("test-counting-layered", false, || Box::<CountingLayered>::default())
        .unwrap();
    assert!(registry.contains("test-counting-layered"));
    assert!(!OperatorRegistry::with_builtins().contains("test-counting-layered"));
    let err = Nekbone::builder(cfg(8, 4, 5))
        .operator("test-counting-layered")
        .build()
        .err()
        .unwrap();
    assert!(err.to_string().contains("test-counting-layered"), "{err}");
}
