//! End-to-end integration: full Nekbone solves across backends, ranked vs
//! serial, and the paper's no-comm roofline mode.

use nekbone::config::RunConfig;
use nekbone::coordinator::{Backend, Nekbone, VectorBackend};
use nekbone::rank::run_ranked;

fn have_artifacts() -> bool {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let ok = std::path::Path::new(dir).join("manifest.json").exists();
    if !ok {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
    }
    ok
}

fn cfg(nelt: usize, n: usize, niter: usize) -> RunConfig {
    RunConfig { nelt, n, niter, ..Default::default() }
}

#[test]
fn xla_backends_match_cpu_end_to_end() {
    if !have_artifacts() {
        return;
    }
    // Full CG: identical residual trajectory on CPU and through PJRT.
    let mut cpu = Nekbone::new(cfg(64, 10, 15), Backend::CpuLayered).unwrap();
    let want = cpu.run().unwrap();
    for variant in ["jnp", "original", "shared", "layered", "layered_unroll2"] {
        let mut app = Nekbone::new(cfg(64, 10, 15), Backend::Xla(variant.into())).unwrap();
        let got = app.run().unwrap();
        let denom = want.final_residual.abs().max(1e-30);
        assert!(
            (got.final_residual - want.final_residual).abs() / denom < 1e-9,
            "{variant}: {} vs {}",
            got.final_residual,
            want.final_residual
        );
    }
}

#[test]
fn xla_padded_mesh_matches_cpu() {
    if !have_artifacts() {
        return;
    }
    // nelt = 100 is not a multiple of the chunk: exercises zero-padding
    // through a complete solve (dssum + mask + CG).
    let mut cpu = Nekbone::new(cfg(100, 10, 10), Backend::CpuLayered).unwrap();
    let want = cpu.run().unwrap();
    let mut app = Nekbone::new(cfg(100, 10, 10), Backend::Xla("layered".into())).unwrap();
    let got = app.run().unwrap();
    let denom = want.final_residual.abs().max(1e-30);
    assert!((got.final_residual - want.final_residual).abs() / denom < 1e-9);
}

#[test]
fn fused_backend_matches_unfused() {
    if !have_artifacts() {
        return;
    }
    let mut plain = Nekbone::new(cfg(64, 10, 12), Backend::Xla("layered".into())).unwrap();
    let want = plain.run().unwrap();
    let mut fused = Nekbone::new(cfg(64, 10, 12), Backend::XlaFused("layered".into())).unwrap();
    let got = fused.run().unwrap();
    let denom = want.final_residual.abs().max(1e-30);
    assert!(
        (got.final_residual - want.final_residual).abs() / denom < 1e-9,
        "fused {} vs {}",
        got.final_residual,
        want.final_residual
    );
}

#[test]
fn fused_no_comm_uses_fused_pap() {
    if !have_artifacts() {
        return;
    }
    // In no-comm, no-mask mode the fused pap is used directly; it must
    // still agree with the plain path.
    let mk = || RunConfig { no_comm: true, no_mask: true, ..cfg(64, 10, 8) };
    let mut plain = Nekbone::new(mk(), Backend::Xla("layered".into())).unwrap();
    let want = plain.run().unwrap();
    let mut fused = Nekbone::new(mk(), Backend::XlaFused("layered".into())).unwrap();
    let got = fused.run().unwrap();
    let denom = want.final_residual.abs().max(1e-30);
    assert!((got.final_residual - want.final_residual).abs() / denom < 1e-9);
}

#[test]
fn vector_backend_xla_matches_rust() {
    if !have_artifacts() {
        return;
    }
    let mut rust_vec = Nekbone::new(cfg(64, 10, 10), Backend::Xla("layered".into())).unwrap();
    let want = rust_vec.run().unwrap();
    let mut xla_vec = Nekbone::new(cfg(64, 10, 10), Backend::Xla("layered".into())).unwrap();
    let got = xla_vec.run_vector_backend(VectorBackend::Xla).unwrap();
    let denom = want.final_residual.abs().max(1e-30);
    assert!(
        (got.final_residual - want.final_residual).abs() / denom < 1e-8,
        "{} vs {}",
        got.final_residual,
        want.final_residual
    );
}

#[test]
fn ranked_matches_serial_on_larger_mesh() {
    let base = RunConfig { nelt: 27, n: 5, niter: 20, ..Default::default() };
    let mut serial = Nekbone::new(base.clone(), Backend::CpuLayered).unwrap();
    let want = serial.run().unwrap();
    for ranks in [1, 3] {
        let got = run_ranked(&RunConfig { ranks, ..base.clone() }).unwrap();
        let denom = want.final_residual.abs().max(1e-30);
        assert!(
            (got.final_residual - want.final_residual).abs() / denom < 1e-6,
            "ranks={ranks}: {} vs {}",
            got.final_residual,
            want.final_residual
        );
    }
}

#[test]
fn chunk_256_matches_chunk_64() {
    if !have_artifacts() {
        return;
    }
    let c64 = cfg(256, 10, 8);
    let c256 = RunConfig { chunk: 256, ..cfg(256, 10, 8) };
    let mut a = Nekbone::new(c64, Backend::Xla("layered".into())).unwrap();
    let mut b = Nekbone::new(c256, Backend::Xla("layered".into())).unwrap();
    let ra = a.run().unwrap();
    let rb = b.run().unwrap();
    let denom = ra.final_residual.abs().max(1e-30);
    assert!((ra.final_residual - rb.final_residual).abs() / denom < 1e-9);
}
