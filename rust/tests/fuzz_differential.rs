//! Differential-fuzz conformance tier: a seeded xorshift case generator
//! drives **every artifact-free registry operator pair** through single
//! applies and full CG solves, asserting agreement at the pair's *joint*
//! precision-tier band (see `util::joint_band` / `util::joint_cg_tol`).
//!
//! The corpus is deterministic: case `i` is drawn entirely from
//! `rhs_seed(MASTER_SEED, i)` through an xorshift64* stream, so every
//! failure message prints the case index, seed, and full configuration —
//! rerunning the suite reproduces it exactly, and
//! `NEKBONE_FUZZ_CASES=<k>` replays just the first `k` cases (or widens
//! the sweep beyond the default).
//!
//! Two arms, both sized by `NEKBONE_FUZZ_CASES` (default
//! [`DEFAULT_CASES`], comfortably over the 200-case floor):
//!
//! * **single applies** — synthetic inputs (no mesh, so no assembly
//!   plan: `cpu-asm*` run their layered fallback), degrees 2..=12, every
//!   pair compared per case;
//! * **full CG** — real mesh/dssum/mask solves through the coordinator
//!   builder, cycling deterministically through the pair list so the
//!   default budget covers every pair at least once. Each side draws its
//!   own `--block-dofs` (`auto|off|64`) and the case draws a
//!   preconditioner (`none|jacobi|cheb`), so the corpus also crosses the
//!   cache-blocked and flat vector pipelines under every preconditioner.
//!   Degrees and element counts are kept large enough that CG stays far
//!   from convergence within the drawn iteration budget — near-converged
//!   residuals would amplify benign rounding differences past any honest
//!   band.

mod util;

use nekbone::config::RunConfig;
use nekbone::coordinator::Nekbone;
use nekbone::operators::{OperatorRegistry, PrecisionTier};
use nekbone::rng::rhs_seed;

/// Master stream for the corpus; case `i` seeds from `rhs_seed(MASTER_SEED, i)`.
const MASTER_SEED: u64 = 0xF0221;

/// Default corpus size per arm: one full cycle of the 210 operator pairs
/// plus slack, and over the 200-case acceptance floor.
const DEFAULT_CASES: usize = 216;

/// Corpus size: `NEKBONE_FUZZ_CASES` when set, [`DEFAULT_CASES`]
/// otherwise. A malformed value is a loud failure (via
/// [`nekbone::config::parse_cases_env`]), never a silent fallback — a CI
/// typo must not quietly shrink the corpus to the default.
fn case_budget() -> usize {
    match std::env::var("NEKBONE_FUZZ_CASES") {
        Err(std::env::VarError::NotPresent) => DEFAULT_CASES,
        Err(e) => panic!("NEKBONE_FUZZ_CASES: {e}"),
        Ok(raw) => {
            nekbone::config::parse_cases_env(&raw).unwrap_or_else(|e| panic!("{e}"))
        }
    }
}

/// xorshift64* — deliberately independent of the crate's own RNG so a
/// library-side reseed or refactor never silently shifts the fuzz corpus.
struct XorShift(u64);

impl XorShift {
    fn new(seed: u64) -> Self {
        XorShift(seed.max(1))
    }

    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    fn below(&mut self, n: usize) -> usize {
        (self.next() % n as u64) as usize
    }

    fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

/// One generated configuration. The apply arm may use the full degree
/// range; the CG arm draws from well-posed ranges (enough interior dofs
/// that the drawn iteration budget never approaches convergence).
#[derive(Debug)]
struct Case {
    index: u64,
    seed: u64,
    apply_n: usize,
    apply_nelt: usize,
    cg_n: usize,
    cg_nelt: usize,
    niter: usize,
    threads: usize,
    precond: &'static str,
    cheb_order: usize,
    decomp: &'static str,
    /// `--block-dofs` for each side of the CG pair — drawn independently,
    /// so the corpus crosses blocked-vs-unblocked vector pipelines (the
    /// blocked walk is bitwise the flat one, so the joint band still
    /// binds). `"64"` forces multi-segment walks at every drawn cg size
    /// (the smallest drawn problem has 4³·4 = 256 dofs).
    block_a: &'static str,
    block_b: &'static str,
}

impl Case {
    fn draw(index: u64) -> Case {
        let seed = rhs_seed(MASTER_SEED, index);
        let mut x = XorShift::new(seed);
        Case {
            index,
            seed,
            apply_n: 2 + x.below(11), // 2..=12: every monomorphized degree
            apply_nelt: *x.pick(&[1usize, 2, 3, 4, 6]),
            cg_n: *x.pick(&[4usize, 5, 6]),
            cg_nelt: *x.pick(&[4usize, 6, 8]),
            niter: 4 + x.below(4), // 4..=7 << interior dof count
            threads: x.below(4),
            precond: *x.pick(&["none", "jacobi", "cheb"]),
            cheb_order: 2 + x.below(3), // 2..=4
            decomp: *x.pick(&["slab", "pencil", "box"]),
            block_a: *x.pick(&["auto", "off", "64"]),
            block_b: *x.pick(&["auto", "off", "64"]),
        }
    }
}

impl std::fmt::Display for Case {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "case {} (seed {:#x}, apply n={} nelt={}, cg n={} nelt={} niter={} \
             precond={} cheb_order={} decomp={}, block a={} b={}, threads={})",
            self.index,
            self.seed,
            self.apply_n,
            self.apply_nelt,
            self.cg_n,
            self.cg_nelt,
            self.niter,
            self.precond,
            self.cheb_order,
            self.decomp,
            self.block_a,
            self.block_b,
            self.threads,
        )
    }
}

/// Every artifact-free operator, sorted — the registry's iteration order
/// is not deterministic, and the CG arm indexes pairs by case number.
fn fuzzable_names(registry: &OperatorRegistry) -> Vec<String> {
    let mut names: Vec<String> = registry
        .names()
        .into_iter()
        .filter(|n| !registry.resolve(n).unwrap().needs_artifacts)
        .collect();
    names.sort();
    assert!(names.len() >= 21, "artifact-free registry shrank: {names:?}");
    names
}

fn tier(registry: &OperatorRegistry, name: &str) -> PrecisionTier {
    registry.resolve(name).unwrap().tier
}

#[test]
fn fuzz_single_applies_agree_for_every_pair_at_the_joint_band() {
    let registry = OperatorRegistry::with_builtins();
    let names = fuzzable_names(&registry);
    for i in 0..case_budget() as u64 {
        let case = Case::draw(i);
        let (n, nelt) = (case.apply_n, case.apply_nelt);
        let np = n * n * n;
        let (u, d, g, c) = util::inputs(case.seed ^ 0xA11, n, nelt);
        let cx = util::ctx(n, nelt, case.threads, "artifacts", &d, &g, &c);
        let outs: Vec<(&str, PrecisionTier, Vec<f64>)> = names
            .iter()
            .map(|name| {
                let mut op = registry
                    .build(name, &cx)
                    .unwrap_or_else(|e| panic!("{case}: build {name}: {e}"));
                let mut w = vec![123.0; nelt * np]; // poisoned
                op.apply(&u, &mut w).unwrap_or_else(|e| panic!("{case}: apply {name}: {e}"));
                (name.as_str(), tier(&registry, name), w)
            })
            .collect();
        for a in 0..outs.len() {
            for b in (a + 1)..outs.len() {
                let band = util::joint_band(outs[a].1, outs[b].1);
                util::assert_agree_at(
                    &outs[b].2,
                    &outs[a].2,
                    band,
                    &format!("{case}: {} vs {}", outs[b].0, outs[a].0),
                );
            }
        }
    }
}

#[test]
fn fuzz_full_cg_agrees_across_the_pair_cycle() {
    let registry = OperatorRegistry::with_builtins();
    let names = fuzzable_names(&registry);
    let mut pairs = Vec::new();
    for a in 0..names.len() {
        for b in (a + 1)..names.len() {
            pairs.push((names[a].clone(), names[b].clone()));
        }
    }
    for i in 0..case_budget() as u64 {
        let case = Case::draw(i);
        let (a, b) = &pairs[i as usize % pairs.len()];
        // Each side draws its own --block-dofs, so the corpus also
        // crosses the blocked and flat vector pipelines (identical
        // trajectories by the blocked-walk contract; any divergence here
        // is a solver bug, not a band issue).
        let mk_cfg = |block: &'static str| RunConfig {
            nelt: case.cg_nelt,
            n: case.cg_n,
            niter: case.niter,
            seed: case.seed,
            cpu_threads: case.threads,
            precond: case.precond.to_string(),
            cheb_order: case.cheb_order,
            decomp: case.decomp.to_string(),
            block_dofs: block.to_string(),
            ..RunConfig::default()
        };
        let run = |name: &str, block: &'static str| {
            let cfg = mk_cfg(block);
            let mut app = Nekbone::builder(cfg.clone())
                .operator(name)
                .build()
                .unwrap_or_else(|e| panic!("{case}: build {name}: {e}"));
            let mut x = vec![0.0; cfg.ndof()];
            let rep = app
                .run_into(Some(&mut x))
                .unwrap_or_else(|e| panic!("{case}: run {name}: {e}"));
            (rep, x)
        };
        let (rep_a, x_a) = run(a, case.block_a);
        let (rep_b, x_b) = run(b, case.block_b);
        let what = format!("{case}: {b} vs {a}");
        assert!(
            rep_a.final_residual.is_finite() && rep_b.final_residual.is_finite(),
            "{what}: non-finite residual ({} vs {})",
            rep_b.final_residual,
            rep_a.final_residual
        );
        assert_eq!(rep_b.iterations, rep_a.iterations, "{what}: iteration count");
        let tol = util::joint_cg_tol(tier(&registry, a), tier(&registry, b));
        let denom = rep_a.final_residual.abs().max(1e-30);
        assert!(
            (rep_b.final_residual - rep_a.final_residual).abs() / denom <= tol,
            "{what}: final residual {} vs {} (tol {tol:e})",
            rep_b.final_residual,
            rep_a.final_residual
        );
        util::assert_within_band(&x_b, &x_a, tol, &what);
    }
}
