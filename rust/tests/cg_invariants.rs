//! Solver-invariant property tier: metamorphic CG laws every registered
//! operator must satisfy, enumerated over [`OperatorRegistry::default`]
//! like the conformance suite — never a hand-written name list.
//!
//! The laws are *exact* (bitwise), not tolerance-banded:
//!
//! * **Power-of-two scaling equivariance** — every CG operation is built
//!   from multiplies, adds, and one square root, all of which commute
//!   bitwise with scaling by a power of two (exponent shifts, no mantissa
//!   rounding). So `solve(2^k · f)` must be bitwise `2^k · solve(f)`:
//!   same iteration count, solution and residual scaled exactly.
//! * **Zero-RHS floor** — a zero right-hand side is exactly converged
//!   before the first iteration: the solver must exit at iteration 0
//!   with a bitwise-zero solution, not divide by zero.
//! * **Reproducibility** — repeated solves against one session (one
//!   workspace, one operator instance) are bitwise identical.
//! * **Blocked-pipeline identity** — `--block-dofs auto` must reproduce
//!   the unblocked trajectory bitwise (solution, residual, rtz1,
//!   `glsc3_sweeps`) while performing exactly `3 × iterations` fewer
//!   full-vector passes, serial and ranked.
//!
//! Coverage is enforced the same way conformance.rs enforces it: the only
//! legitimate skip is an artifact-backed operator on a host without AOT
//! artifacts, and tested + gated must equal the whole registry.

use std::collections::BTreeSet;

use nekbone::config::RunConfig;
use nekbone::coordinator::Nekbone;
use nekbone::operators::OperatorRegistry;
use nekbone::rank::run_ranked_with;
use nekbone::rng::Rng;

fn artifacts_dir() -> &'static str {
    concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")
}

fn artifacts_present() -> bool {
    std::path::Path::new(artifacts_dir()).join("manifest.json").exists()
}

/// Run `check(name)` on every canonical operator in the default registry,
/// then assert nothing was skipped (see `conformance.rs` — same policy:
/// only `needs_artifacts` operators may be gated, and only when the
/// artifacts are absent).
fn for_every_operator(mut check: impl FnMut(&str)) {
    let registry = OperatorRegistry::default();
    let all: BTreeSet<String> = registry.names().into_iter().collect();
    assert!(!all.is_empty(), "default registry is empty");
    let mut tested = BTreeSet::new();
    let mut gated = BTreeSet::new();
    for name in &all {
        let spec = registry.resolve(name).expect("canonical names resolve");
        if spec.needs_artifacts && !artifacts_present() {
            gated.insert(name.clone());
            continue;
        }
        check(name);
        tested.insert(name.clone());
    }
    let covered: BTreeSet<String> = tested.union(&gated).cloned().collect();
    assert_eq!(covered, all, "invariant suite skipped a registered operator");
    for name in &gated {
        assert!(
            registry.resolve(name).unwrap().needs_artifacts,
            "{name} was gated without declaring an artifact requirement"
        );
    }
    assert!(!tested.is_empty(), "invariant suite exercised no operator at all");
}

fn cfg(block_dofs: &str) -> RunConfig {
    RunConfig {
        nelt: 4,
        n: 4,
        niter: 10,
        artifacts_dir: artifacts_dir().to_string(),
        block_dofs: block_dofs.into(),
        ..RunConfig::default()
    }
}

fn assert_bits_eq(got: &[f64], want: &[f64], what: &str, name: &str) {
    assert_eq!(got.len(), want.len(), "{name}: {what} length");
    for (i, (&g, &w)) in got.iter().zip(want).enumerate() {
        assert_eq!(
            g.to_bits(),
            w.to_bits(),
            "{name}: {what}[{i}] diverges ({g} vs {w})"
        );
    }
}

#[test]
fn power_of_two_rhs_scaling_is_bitwise_equivariant() {
    // solve(8 f) vs 8 · solve(f): staging (dssum is adds, the mask is
    // 0/1 multiplies), the operator (multiplies by fixed d/g factors —
    // f64 or f32-stored — and adds), every dot product, and the exit
    // sqrt all scale exactly under a power of two, so the trajectories
    // must match to the bit, not within a band.
    const SCALE: f64 = 8.0;
    for_every_operator(|name| {
        let mut app = Nekbone::builder(cfg("auto")).operator(name).build().unwrap();
        let mut session = app.session();
        let ndof = session.solution().len();
        let f = Rng::new(0x10A0).normal_vec(ndof);
        let rep1 = session.solve(&f).unwrap();
        let x1 = session.solution().to_vec();
        let scaled: Vec<f64> = f.iter().map(|&v| SCALE * v).collect();
        let rep2 = session.solve(&scaled).unwrap();
        assert_eq!(rep1.iterations, rep2.iterations, "{name}: iteration count");
        assert_eq!(
            rep2.final_rnorm.to_bits(),
            (SCALE * rep1.final_rnorm).to_bits(),
            "{name}: residual must scale exactly by {SCALE}"
        );
        assert_eq!(
            rep2.rtz1.to_bits(),
            (SCALE * SCALE * rep1.rtz1).to_bits(),
            "{name}: rtz1 must scale exactly by {}",
            SCALE * SCALE
        );
        let want: Vec<f64> = x1.iter().map(|&v| SCALE * v).collect();
        assert_bits_eq(session.solution(), &want, "solution", name);
    });
}

#[test]
fn zero_rhs_converges_exactly_at_iteration_zero() {
    for_every_operator(|name| {
        let mut app = Nekbone::builder(cfg("auto")).operator(name).build().unwrap();
        let mut session = app.session();
        let ndof = session.solution().len();
        let rep = session.solve(&vec![0.0; ndof]).unwrap();
        assert_eq!(rep.iterations, 0, "{name}: zero rhs must converge before iter 1");
        assert_eq!(rep.final_rnorm.to_bits(), 0.0f64.to_bits(), "{name}: exit residual");
        assert!(
            session.solution().iter().all(|&v| v.to_bits() == 0.0f64.to_bits()),
            "{name}: solution of the zero system must be bitwise zero"
        );
    });
}

#[test]
fn repeated_solves_on_one_workspace_are_bitwise_reproducible() {
    for_every_operator(|name| {
        let mut app = Nekbone::builder(cfg("auto")).operator(name).build().unwrap();
        let mut session = app.session();
        let ndof = session.solution().len();
        let f = Rng::new(0x10A2).normal_vec(ndof);
        let rep1 = session.solve(&f).unwrap();
        let x1 = session.solution().to_vec();
        let rep2 = session.solve(&f).unwrap();
        assert_eq!(rep1.iterations, rep2.iterations, "{name}: iteration count");
        assert_eq!(rep1.final_rnorm.to_bits(), rep2.final_rnorm.to_bits(), "{name}: rnorm");
        assert_eq!(rep1.rtz1.to_bits(), rep2.rtz1.to_bits(), "{name}: rtz1");
        assert_eq!(rep1.glsc3_sweeps, rep2.glsc3_sweeps, "{name}: glsc3 sweeps");
        assert_eq!(rep1.vector_sweeps, rep2.vector_sweeps, "{name}: vector sweeps");
        assert_bits_eq(session.solution(), &x1, "solution", name);
    });
}

#[test]
fn blocked_pipeline_is_bitwise_identical_and_strictly_cheaper() {
    // The tentpole contract, policed registry-wide: cache-blocking the
    // vector pipeline changes *nothing* about the trajectory — solution,
    // residual, rtz1, iteration count, and glsc3 accounting are bitwise
    // the unblocked run's — while `vector_sweeps` drops by exactly 3 per
    // iteration (z production, the rtz read, and one of the two tail
    // updates each fold into a shared cache-resident walk).
    for_every_operator(|name| {
        let run = |block: &str| {
            let mut app = Nekbone::builder(cfg(block)).operator(name).build().unwrap();
            let mut session = app.session();
            let ndof = session.solution().len();
            let f = Rng::new(0x10A3).normal_vec(ndof);
            let rep = session.solve(&f).unwrap();
            (rep, session.solution().to_vec())
        };
        let (flat, x_flat) = run("off");
        let (blocked, x_blocked) = run("auto");
        assert_eq!(flat.iterations, blocked.iterations, "{name}: iteration count");
        assert_eq!(flat.final_rnorm.to_bits(), blocked.final_rnorm.to_bits(), "{name}: rnorm");
        assert_eq!(flat.rtz1.to_bits(), blocked.rtz1.to_bits(), "{name}: rtz1");
        assert_eq!(flat.glsc3_sweeps, blocked.glsc3_sweeps, "{name}: glsc3 sweeps");
        assert_bits_eq(&x_blocked, &x_flat, "solution", name);
        assert!(
            blocked.vector_sweeps < flat.vector_sweeps,
            "{name}: blocking must strictly reduce vector passes ({} vs {})",
            blocked.vector_sweeps,
            flat.vector_sweeps
        );
        assert_eq!(
            flat.vector_sweeps - blocked.vector_sweeps,
            3 * blocked.iterations,
            "{name}: the blocked walk must save exactly 3 passes per iteration"
        );
        assert!(
            flat.vector_sweeps - blocked.vector_sweeps >= 3 * cfg("auto").niter,
            "{name}: acceptance floor — at least 3·niter passes saved"
        );
    });
}

#[test]
fn ranked_blocked_solves_match_unblocked_bitwise() {
    // Same identity through the rank runtime: per-rank workspaces get
    // smaller local dof counts (the global --block-dofs knob clamps per
    // rank), and the ordered-gid fold keeps every reduction — and hence
    // the whole trajectory — bitwise the serial, unblocked one.
    for_every_operator(|name| {
        let run = |block: &str, ranks: usize, decomp: &str| {
            let rc = RunConfig {
                nelt: 8,
                n: 3,
                niter: 6,
                ranks,
                decomp: decomp.into(),
                artifacts_dir: artifacts_dir().to_string(),
                block_dofs: block.into(),
                ..RunConfig::default()
            };
            run_ranked_with(&rc, name).unwrap()
        };
        // Compare blocked vs unblocked at the *same* decomposition: a
        // fused operator's ranked pap folds per-rank (tolerance-checked
        // against serial, not bitwise), so the bitwise law here is that
        // blocking never changes whatever trajectory a decomposition
        // produces.
        for (ranks, decomp) in [(1, "slab"), (2, "slab"), (4, "pencil")] {
            let flat = run("off", ranks, decomp);
            let blocked = run("auto", ranks, decomp);
            assert_eq!(
                flat.iterations, blocked.iterations,
                "{name} ({decomp}×{ranks}): iteration count"
            );
            assert_eq!(
                flat.final_residual.to_bits(),
                blocked.final_residual.to_bits(),
                "{name} ({decomp}×{ranks}): blocked ranked residual must be bitwise \
                 the unblocked one ({} vs {})",
                blocked.final_residual,
                flat.final_residual
            );
        }
    });
}
