//! Offline stub of the PJRT/XLA bindings.
//!
//! The real `xla` crate links the native PJRT CPU client and can compile and
//! execute the AOT-lowered HLO artifacts under `artifacts/`. That native
//! library is not available in every build environment, so this stub provides
//! the same API surface with runtime types that **cannot be constructed**:
//! every entry point (`PjRtClient::cpu`, `HloModuleProto::from_text_file`)
//! returns a descriptive error, and all downstream types are uninhabited, so
//! the methods on them are statically unreachable.
//!
//! The nekbone crate treats that error exactly like "artifacts not built":
//! CPU backends run normally, XLA backends fail fast at setup with a clear
//! message, and artifact-gated tests skip. Swapping this path dependency for
//! the real crate (same module paths, same signatures) enables the PJRT path
//! with no source changes.

use std::fmt;

/// Error type mirroring the real bindings' error.
pub struct Error(String);

impl Error {
    pub fn new(msg: impl Into<String>) -> Self {
        Error(msg.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla::Error({:?})", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: the native PJRT/XLA runtime is unavailable in this build \
         (offline stub); link the real `xla` crate to execute AOT artifacts"
    ))
}

/// PJRT client handle. Uninhabited in the stub: [`PjRtClient::cpu`] is the
/// only constructor and it always errors, so instance methods are
/// statically unreachable (`match *self {}`).
pub enum PjRtClient {}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        match *self {}
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        match *self {}
    }

    pub fn buffer_from_host_buffer(
        &self,
        _data: &[f64],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        match *self {}
    }
}

/// Parsed HLO module text.
pub enum HloModuleProto {}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<Self> {
        Err(unavailable(&format!("HloModuleProto::from_text_file({path:?})")))
    }
}

/// A computation ready for compilation.
pub enum XlaComputation {}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> Self {
        match *proto {}
    }
}

/// A compiled, loaded executable.
pub enum PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        match *self {}
    }
}

/// A device-resident buffer.
pub enum PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        match *self {}
    }
}

/// A host-side literal value.
pub enum Literal {}

impl Literal {
    pub fn to_tuple1(self) -> Result<Literal> {
        match self {}
    }

    pub fn to_tuple2(self) -> Result<(Literal, Literal)> {
        match self {}
    }

    pub fn copy_raw_to(&self, _dst: &mut [f64]) -> Result<()> {
        match *self {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_constructor_reports_stub() {
        let err = PjRtClient::cpu().err().expect("stub must not construct");
        let msg = err.to_string();
        assert!(msg.contains("offline stub"), "{msg}");
    }

    #[test]
    fn hlo_loader_reports_stub() {
        let err = HloModuleProto::from_text_file("x.hlo.txt").err().unwrap();
        assert!(err.to_string().contains("unavailable"), "{err}");
    }
}
