//! Strong scaling over simulated MPI ranks (experiment E8) and the paper's
//! problem-size observation: below ~500k dofs per device, adding devices
//! beats nothing — small inputs are overhead-dominated (paper section VII).
//!
//! ```bash
//! cargo run --release --example strong_scaling
//! ```

use nekbone::bench::Table;
use nekbone::config::RunConfig;
use nekbone::coordinator::Nekbone;
use nekbone::rank::run_ranked;

fn main() -> nekbone::Result<()> {
    println!("== strong scaling: fixed problem, more simulated ranks ==");
    // ez = 8 layers for nelt=512 (8x8x8) -> up to 8 slab ranks.
    let base = RunConfig { nelt: 512, n: 6, niter: 50, ..RunConfig::default() };
    println!(
        "problem: {} elements, degree {}, {} local dofs, {} CG iterations\n",
        base.nelt,
        base.n - 1,
        base.ndof(),
        base.niter
    );

    let mut table = Table::new(&["ranks", "time(s)", "speedup", "efficiency", "residual"]);
    let mut t1 = None;
    for ranks in [1usize, 2, 4, 8] {
        let cfg = RunConfig { ranks, ..base.clone() };
        let rep = run_ranked(&cfg)?;
        let t = rep.seconds;
        let t_base = *t1.get_or_insert(t);
        table.row(&[
            ranks.to_string(),
            format!("{t:.3}"),
            format!("{:.2}x", t_base / t),
            format!("{:.0}%", 100.0 * t_base / t / ranks as f64),
            format!("{:.3e}", rep.final_residual),
        ]);
    }
    table.print();
    println!(
        "\n(threads share {} hardware cores, so wall-clock speedup saturates at the\n\
         core count; the point of the experiment is the communication structure:\n\
         identical residuals prove the halo exchange + allreduce path)",
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    );

    // Paper section VII: performance vs dofs-per-device. Sweep problem
    // size on one device and report GFlop/s — the knee is where the device
    // stops being overhead-bound (the "<500k dofs is not beneficial" claim).
    println!("\n== problem-size dependence (single device, xla-layered) ==");
    let have_artifacts = std::path::Path::new("artifacts").join("manifest.json").exists();
    let operator = if have_artifacts {
        "xla-layered"
    } else {
        eprintln!("(artifacts not built; using cpu-layered)");
        "cpu-layered"
    };
    let mut table = Table::new(&["nelt", "dof", "GFlop/s", "GF/s per 100k dof"]);
    for nelt in [8usize, 32, 64, 128, 256, 512, 1024] {
        let cfg = RunConfig { nelt, n: 10, niter: 20, ..RunConfig::default() };
        let dof = cfg.ndof();
        let mut app = Nekbone::builder(cfg).operator(operator).build()?;
        let rep = app.run()?;
        table.row(&[
            nelt.to_string(),
            dof.to_string(),
            format!("{:.3}", rep.gflops()),
            format!("{:.3}", rep.gflops() / (dof as f64 / 1e5)),
        ]);
    }
    table.print();
    Ok(())
}
