//! Measured-roofline comparison (paper Fig. 4 methodology as a standalone
//! example): measure copy bandwidth for each problem size, derive the
//! roofline from the paper's intensity I(n) = (12n+34)/240, and compare the
//! achieved performance of the optimized kernel with communication off.
//!
//! ```bash
//! cargo run --release --example roofline
//! ```

use nekbone::bench::Table;
use nekbone::config::RunConfig;
use nekbone::coordinator::Nekbone;
use nekbone::metrics::CostModel;
use nekbone::roofline::measure_bandwidth;

fn main() -> nekbone::Result<()> {
    let have_artifacts = std::path::Path::new("artifacts").join("manifest.json").exists();
    let operator = if have_artifacts {
        "xla-layered"
    } else {
        eprintln!("(artifacts not built; using cpu-layered)");
        "cpu-layered"
    };
    let n = 10;

    println!("== measured roofline (paper Fig. 4 methodology) ==");
    println!("intensity I({n}) = {:.4} flop/byte; comm off on both sides\n", CostModel::new(n, 1).intensity());

    let mut table = Table::new(&[
        "nelt",
        "dof",
        "bw(GB/s)",
        "roofline(GF/s)",
        "achieved(GF/s)",
        "fraction",
    ]);
    for nelt in [64usize, 256, 512, 1024, 2048, 4096] {
        let cm = CostModel::new(n, nelt);
        let bw = measure_bandwidth(cm.dof, 5);
        let roof = cm.roofline_gflops(bw.bandwidth_gbs);
        let cfg = RunConfig { nelt, n, niter: 20, no_comm: true, ..RunConfig::default() };
        let mut app = Nekbone::builder(cfg).operator(operator).build()?;
        let rep = app.run()?;
        let achieved = rep.gflops();
        table.row(&[
            nelt.to_string(),
            cm.dof.to_string(),
            format!("{:.2}", bw.bandwidth_gbs),
            format!("{roof:.3}"),
            format!("{achieved:.3}"),
            format!("{:.1}%", 100.0 * achieved / roof),
        ]);
    }
    table.print();
    println!(
        "\npaper reference: 78/87/92% of the measured roofline at 1024/2048/4096\n\
         elements (P100); 77/84/88% (V100). The fraction should rise with the\n\
         problem size as launch overhead amortizes."
    );
    Ok(())
}
