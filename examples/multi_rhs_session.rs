//! Multi-RHS serving through a `SolveSession`: build the application once,
//! then answer a stream of right-hand sides with zero per-solve setup —
//! the "one setup, many requests" shape of a production deployment.
//!
//! ```bash
//! cargo run --release --example multi_rhs_session
//! ```
//!
//! Needs no artifacts (CPU operator); pass a different operator name as
//! the first argument to try others, e.g. `cpu-threaded-fused`.

use std::time::Instant;

use nekbone::config::RunConfig;
use nekbone::coordinator::Nekbone;

fn main() -> nekbone::Result<()> {
    let operator = std::env::args().nth(1).unwrap_or_else(|| "cpu-layered".into());
    let cfg = RunConfig { nelt: 64, n: 8, niter: 50, ..RunConfig::default() };

    println!("== multi-RHS session ({operator}) ==");
    let t0 = Instant::now();
    let mut app = Nekbone::builder(cfg).operator(operator.as_str()).build()?;
    let setup_s = t0.elapsed().as_secs_f64();
    let ndof = app.mesh().ndof_local();
    println!("setup: {setup_s:.3}s for {ndof} local dofs");

    // A batch of independent loads, as one burst...
    let batch: Vec<Vec<f64>> =
        (0..4u64).map(|s| nekbone::rng::Rng::new(s).normal_vec(ndof)).collect();
    let mut session = app.session();
    let t1 = Instant::now();
    let reports = session.solve_batch(&batch)?;
    let batch_s = t1.elapsed().as_secs_f64();
    for (i, rep) in reports.iter().enumerate() {
        println!("  batch rhs {i}: {} iters, |r| = {:.3e}", rep.iterations, rep.final_rnorm);
    }
    println!("batch of {}: {batch_s:.3}s total, {:.3}s/solve", batch.len(), batch_s / 4.0);

    // ...then a trickle of single requests against the same session.
    for seed in 100..103u64 {
        let rhs = nekbone::rng::Rng::new(seed).normal_vec(ndof);
        let t = Instant::now();
        let rep = session.solve(&rhs)?;
        println!(
            "  request {}: {} iters, |r| = {:.3e}, {:.3}s (no re-setup)",
            session.solves(),
            rep.iterations,
            rep.final_rnorm,
            t.elapsed().as_secs_f64()
        );
    }
    Ok(())
}
