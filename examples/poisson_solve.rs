//! End-to-end validation driver: solve an actual Poisson problem with a
//! manufactured solution through the full stack (mesh → geometry →
//! gather–scatter → AOT kernel via PJRT → CG) and report discretization
//! error against the analytic solution.
//!
//!   -∇²u = f  on (0,1)³,  u = 0 on the boundary,
//!   u*(x,y,z) = sin(πx) sin(πy) sin(πz),  f = 3π² u*.
//!
//! The SEM load vector is b_i = w_i |J| f(x_i); solving A x = b must
//! reproduce u* at the GLL nodes with spectrally decreasing error as the
//! polynomial degree grows — if any layer (kernel, geometry, dssum, CG)
//! were wrong, the error would not converge. Results are recorded in
//! EXPERIMENTS.md.
//!
//! ```bash
//! cargo run --release --example poisson_solve
//! ```

use std::f64::consts::PI;

use nekbone::basis::Basis;
use nekbone::config::RunConfig;
use nekbone::coordinator::Nekbone;

fn solve_for_degree(n: usize, nelt: usize, operator: &str) -> nekbone::Result<(f64, f64)> {
    let cfg = RunConfig { nelt, n, niter: 600, ..RunConfig::default() };
    let mut app = Nekbone::builder(cfg).operator(operator).build()?;
    let mesh = app.mesh().clone();
    let basis = Basis::new(n);
    let (xs, ys, zs) = mesh.coordinates(&basis.points);

    // Manufactured load: b_i = w_i |J| * 3π² u*(x_i) per element copy
    // (dssum inside set_rhs assembles the shared nodes).
    let np = n * n * n;
    let mut b = vec![0.0; mesh.ndof_local()];
    for e in 0..mesh.nelt() {
        let (lo, hi) = mesh.element_bounds(e);
        let detj = (hi[0] - lo[0]) * (hi[1] - lo[1]) * (hi[2] - lo[2]) / 8.0;
        for k in 0..n {
            for j in 0..n {
                for i in 0..n {
                    let idx = e * np + (k * n + j) * n + i;
                    let w = basis.weights[i] * basis.weights[j] * basis.weights[k];
                    let ustar =
                        (PI * xs[idx]).sin() * (PI * ys[idx]).sin() * (PI * zs[idx]).sin();
                    b[idx] = w * detj * 3.0 * PI * PI * ustar;
                }
            }
        }
    }
    app.set_rhs(&b)?;

    let mut x = vec![0.0; mesh.ndof_local()];
    let _report = app.run_into(Some(&mut x))?;

    // Error against the analytic solution at the GLL nodes.
    let mut linf = 0.0f64;
    let mut l2 = 0.0f64;
    let mut vol = 0.0f64;
    for e in 0..mesh.nelt() {
        let (lo, hi) = mesh.element_bounds(e);
        let detj = (hi[0] - lo[0]) * (hi[1] - lo[1]) * (hi[2] - lo[2]) / 8.0;
        for k in 0..n {
            for j in 0..n {
                for i in 0..n {
                    let idx = e * np + (k * n + j) * n + i;
                    let ustar =
                        (PI * xs[idx]).sin() * (PI * ys[idx]).sin() * (PI * zs[idx]).sin();
                    let err = x[idx] - ustar;
                    linf = linf.max(err.abs());
                    let w = basis.weights[i] * basis.weights[j] * basis.weights[k] * detj;
                    l2 += w * err * err;
                    vol += w;
                }
            }
        }
    }
    Ok((linf, (l2 / vol).sqrt()))
}

fn main() -> nekbone::Result<()> {
    let have_artifacts = std::path::Path::new("artifacts").join("manifest.json").exists();
    println!("== poisson_solve: manufactured-solution validation ==");
    println!("u* = sin(πx)sin(πy)sin(πz) on (0,1)^3, 8 elements\n");
    println!("{:>6} {:>14} {:>14}  backend", "degree", "L_inf error", "L2 error");

    // CPU path: spectral convergence sweep over the polynomial degree.
    let mut last = f64::INFINITY;
    for n in [3usize, 5, 7, 9] {
        let (linf, l2) = solve_for_degree(n, 8, "cpu-layered")?;
        println!("{:>6} {:>14.3e} {:>14.3e}  cpu-layered", n - 1, linf, l2);
        assert!(
            linf < last / 5.0 || linf < 1e-9,
            "no spectral convergence: {linf} after {last}"
        );
        last = linf;
    }

    // The paper's configuration through the full AOT/PJRT path.
    if have_artifacts {
        let (linf, l2) = solve_for_degree(10, 8, "xla-layered")?;
        println!("{:>6} {:>14.3e} {:>14.3e}  xla-layered (AOT/PJRT)", 9, linf, l2);
        assert!(linf < 1e-7, "degree-9 XLA solve too inaccurate: {linf}");
    } else {
        eprintln!("(artifacts not built; skipping the XLA leg — run `make artifacts`)");
    }
    println!("\nspectral convergence confirmed: all layers compose correctly");
    Ok(())
}
