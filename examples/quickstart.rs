//! Quickstart: run Nekbone at the paper's configuration (polynomial degree
//! 9, 100 CG iterations) on a small mesh and print the report.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! The operator is selected **by name** from the operator registry through
//! the application builder. Requires `make artifacts` for the XLA
//! operators; falls back to the CPU operator with a note otherwise.

use nekbone::config::RunConfig;
use nekbone::coordinator::Nekbone;

fn main() -> nekbone::Result<()> {
    let cfg = RunConfig {
        nelt: 64,
        n: 10,    // polynomial degree 9, the paper's setting
        niter: 100,
        ..RunConfig::default()
    };

    // Prefer the paper's optimized kernel through the AOT/PJRT path.
    let operator = if std::path::Path::new(&cfg.artifacts_dir).join("manifest.json").exists() {
        "xla-layered"
    } else {
        eprintln!("note: artifacts not built (run `make artifacts`); using the CPU operator");
        "cpu-layered"
    };

    println!("== nekbone-rs quickstart ==");
    println!(
        "mesh: {} elements, degree {}, {} local dofs, operator {}",
        cfg.nelt,
        cfg.n - 1,
        cfg.ndof(),
        operator
    );

    let mut app = Nekbone::builder(cfg).operator(operator).build()?;
    let report = app.run()?;

    println!("{}", report.summary());
    let cm = report.cost_model();
    println!("cost model (paper Eq. 1-2):");
    println!("  flops/iter        : {}", cm.flops_per_iter());
    println!("  bytes/iter        : {}", cm.bytes_per_iter());
    println!("  intensity         : {:.4} flop/byte", cm.intensity());
    println!("achieved             : {:.3} GFlop/s", report.gflops());
    println!("kernel-level (Ax)    : {:.3} GFlop/s", report.ax_gflops());
    Ok(())
}
