"""Kernel-vs-oracle tests: every Pallas Ax variant must agree with the
pure-jnp reference over a hypothesis sweep of shapes, dtypes, and random
affine geometry (DESIGN.md section 9)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import basis
from compile.kernels import (
    AX_VARIANTS,
    SHARED_BUDGET_BYTES,
    SharedCapacityError,
    ax_layered,
    ax_ref,
    ax_shared,
    grad_ref,
    shared_bytes,
)

PALLAS_VARIANTS = [k for k in AX_VARIANTS if k != "jnp"]


def rand_inputs(rng, nelt, n, dtype=np.float64, spd_geometry=False):
    u = rng.standard_normal((nelt, n, n, n)).astype(dtype)
    d = basis.derivative_matrix(n).astype(dtype)
    if spd_geometry:
        # Geometric factors from a random SPD 3x3 per gridpoint - what a
        # real (non-degenerate) mesh produces.
        a = rng.standard_normal((nelt, n, n, n, 3, 3)).astype(dtype)
        m = np.einsum("...ij,...kj->...ik", a, a) + 0.5 * np.eye(3, dtype=dtype)
        g = np.stack(
            [m[..., 0, 0], m[..., 0, 1], m[..., 0, 2],
             m[..., 1, 1], m[..., 1, 2], m[..., 2, 2]],
            axis=1,
        )
    else:
        g = rng.standard_normal((nelt, 6, n, n, n)).astype(dtype)
    return u, d, g


def tol_for(dtype):
    return dict(rtol=2e-4, atol=2e-4) if dtype == np.float32 else dict(rtol=1e-11, atol=1e-11)


# ------------------------------------------------------ hypothesis sweeps
@pytest.mark.parametrize("variant", PALLAS_VARIANTS)
@given(
    n=st.integers(min_value=2, max_value=8),
    nelt=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2**31),
    dtype=st.sampled_from([np.float64, np.float32]),
)
@settings(max_examples=12, deadline=None)
def test_variant_matches_ref(variant, n, nelt, seed, dtype):
    rng = np.random.default_rng(seed)
    u, d, g = rand_inputs(rng, nelt, n, dtype)
    want = np.asarray(ax_ref(jnp.asarray(u), jnp.asarray(d), jnp.asarray(g)))
    got = np.asarray(AX_VARIANTS[variant](jnp.asarray(u), jnp.asarray(d), jnp.asarray(g)))
    np.testing.assert_allclose(got, want, **tol_for(dtype))


@pytest.mark.parametrize("variant", PALLAS_VARIANTS)
def test_variant_paper_configuration(variant):
    """The paper's configuration: polynomial degree 9 (n = 10), f64."""
    rng = np.random.default_rng(42)
    u, d, g = rand_inputs(rng, 2, 10, spd_geometry=True)
    want = np.asarray(ax_ref(u, d, g))
    got = np.asarray(AX_VARIANTS[variant](u, d, g))
    np.testing.assert_allclose(got, want, rtol=1e-11, atol=1e-11)


# --------------------------------------------------------- operator algebra
@given(seed=st.integers(min_value=0, max_value=2**31))
@settings(max_examples=10, deadline=None)
def test_ax_is_symmetric_for_symmetric_geometry(seed):
    """<A u, v> = <u, A v> - the local operator is symmetric because G is a
    symmetric tensor; this is what makes CG applicable at all."""
    rng = np.random.default_rng(seed)
    n, nelt = 5, 2
    u, d, g = rand_inputs(rng, nelt, n, spd_geometry=True)
    v = rng.standard_normal(u.shape)
    au = np.asarray(ax_ref(u, d, g))
    av = np.asarray(ax_ref(v, d, g))
    np.testing.assert_allclose(np.sum(au * v), np.sum(u * av), rtol=1e-9)


@given(seed=st.integers(min_value=0, max_value=2**31))
@settings(max_examples=10, deadline=None)
def test_ax_positive_semidefinite(seed):
    """<A u, u> >= 0 for SPD geometric factors (A = D^T G D)."""
    rng = np.random.default_rng(seed)
    u, d, g = rand_inputs(rng, 2, 5, spd_geometry=True)
    au = np.asarray(ax_ref(u, d, g))
    assert np.sum(au * u) >= -1e-9


def test_ax_kills_constants():
    """A constant field has zero gradient: A 1 = 0 (pure Neumann locally)."""
    n = 6
    d = basis.derivative_matrix(n)
    rng = np.random.default_rng(3)
    u = np.ones((2, n, n, n))
    g = rng.standard_normal((2, 6, n, n, n))
    np.testing.assert_allclose(np.asarray(ax_ref(u, d, g)), 0.0, atol=1e-10)


@given(seed=st.integers(min_value=0, max_value=2**31))
@settings(max_examples=10, deadline=None)
def test_ax_linear(seed):
    rng = np.random.default_rng(seed)
    u, d, g = rand_inputs(rng, 2, 4)
    v = rng.standard_normal(u.shape)
    a, b = 1.7, -0.3
    lhs = np.asarray(ax_ref(a * u + b * v, d, g))
    rhs = a * np.asarray(ax_ref(u, d, g)) + b * np.asarray(ax_ref(v, d, g))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-10, atol=1e-10)


def test_grad_ref_on_linear_field():
    """The r-derivative of u = r (the GLL coordinate) is exactly 1."""
    n = 7
    x = basis.gll_points(n)
    d = basis.derivative_matrix(n)
    u = np.broadcast_to(x, (1, n, n, n)).copy()  # varies along i (r)
    wr, ws, wt = (np.asarray(a) for a in grad_ref(jnp.asarray(u), jnp.asarray(d)))
    np.testing.assert_allclose(wr, 1.0, atol=1e-10)
    np.testing.assert_allclose(ws, 0.0, atol=1e-10)
    np.testing.assert_allclose(wt, 0.0, atol=1e-10)


# ------------------------------------------------- the capacity wall (E7)
def test_shared_capacity_wall_matches_paper():
    """f64: n = 10 fits, n = 11 does not - exactly the paper's P100 wall
    ('does not work for elements with more than 10 GLL points')."""
    assert shared_bytes(10) <= SHARED_BUDGET_BYTES
    assert shared_bytes(11) > SHARED_BUDGET_BYTES


def test_shared_raises_above_wall():
    rng = np.random.default_rng(0)
    u, d, g = rand_inputs(rng, 1, 11)
    with pytest.raises(SharedCapacityError):
        ax_shared(u, d, g)


def test_layered_works_above_wall():
    """The paper's variant is not shared-memory-bound: n = 12 builds and is
    correct ('can, by only changing a few constants, be ported to other
    polynomial degrees')."""
    rng = np.random.default_rng(1)
    u, d, g = rand_inputs(rng, 1, 12)
    want = np.asarray(ax_ref(u, d, g))
    got = np.asarray(ax_layered(u, d, g))
    np.testing.assert_allclose(got, want, rtol=1e-11, atol=1e-11)


def test_shared_f32_fits_above_f64_wall():
    """The wall is a byte budget, not a point count: f32 halves the
    footprint so n = 11 fits again."""
    assert shared_bytes(11, itemsize=4) <= SHARED_BUDGET_BYTES
    rng = np.random.default_rng(2)
    u, d, g = rand_inputs(rng, 1, 11, dtype=np.float32)
    want = np.asarray(ax_ref(u, d, g))
    got = np.asarray(ax_shared(u, d, g))
    np.testing.assert_allclose(got, want, rtol=3e-3, atol=3e-3)
