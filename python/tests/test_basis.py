"""Tests for the GLL basis (python twin of rust/src/basis)."""

import numpy as np
import pytest
from hypothesis import given, strategies as st

from compile import basis


# ------------------------------------------------------------- closed forms
def test_gll_points_n2():
    np.testing.assert_allclose(basis.gll_points(2), [-1.0, 1.0])


def test_gll_points_n3():
    np.testing.assert_allclose(basis.gll_points(3), [-1.0, 0.0, 1.0], atol=1e-15)


def test_gll_points_n4():
    r = 1.0 / np.sqrt(5.0)
    np.testing.assert_allclose(basis.gll_points(4), [-1.0, -r, r, 1.0], atol=1e-14)


def test_gll_points_n5():
    r = np.sqrt(3.0 / 7.0)
    np.testing.assert_allclose(basis.gll_points(5), [-1.0, -r, 0.0, r, 1.0], atol=1e-14)


def test_gll_weights_n2():
    np.testing.assert_allclose(basis.gll_weights(2), [1.0, 1.0])


def test_gll_weights_n3():
    np.testing.assert_allclose(basis.gll_weights(3), [1 / 3, 4 / 3, 1 / 3], atol=1e-14)


def test_gll_weights_n5():
    # Known: [1/10, 49/90, 32/45, 49/90, 1/10]
    np.testing.assert_allclose(
        basis.gll_weights(5),
        [0.1, 49 / 90, 32 / 45, 49 / 90, 0.1],
        atol=1e-14,
    )


# --------------------------------------------------------------- invariants
@given(st.integers(min_value=2, max_value=24))
def test_points_sorted_symmetric_in_range(n):
    x = basis.gll_points(n)
    assert x[0] == -1.0 and x[-1] == 1.0
    assert np.all(np.diff(x) > 0)
    np.testing.assert_allclose(x, -x[::-1], atol=1e-14)


@given(st.integers(min_value=2, max_value=24))
def test_weights_positive_sum_two(n):
    w = basis.gll_weights(n)
    assert np.all(w > 0)
    np.testing.assert_allclose(w.sum(), 2.0, rtol=1e-13)


@given(st.integers(min_value=2, max_value=16), st.integers(min_value=0, max_value=40))
def test_quadrature_exactness(n, seed):
    """GLL quadrature is exact for polynomials of degree <= 2n - 3."""
    deg = min(2 * n - 3, 12)
    if deg < 0:
        return
    rng = np.random.default_rng(seed)
    coeffs = rng.standard_normal(deg + 1)
    x, w = basis.gll_points(n), basis.gll_weights(n)
    quad = np.sum(w * np.polyval(coeffs, x))
    exact = sum(
        c / (deg - i + 1) * (1 ** (deg - i + 1) - (-1) ** (deg - i + 1))
        for i, c in enumerate(coeffs)
    )
    np.testing.assert_allclose(quad, exact, rtol=1e-10, atol=1e-10)


@given(st.integers(min_value=2, max_value=16))
def test_derivative_matrix_exact_on_monomials(n):
    """D must differentiate every monomial of degree <= n-1 exactly."""
    x = basis.gll_points(n)
    d = basis.derivative_matrix(n)
    for p in range(n):
        u = x**p
        du = p * x ** (p - 1) if p > 0 else np.zeros_like(x)
        np.testing.assert_allclose(d @ u, du, atol=5e-10)


@given(st.integers(min_value=2, max_value=20))
def test_derivative_matrix_rows_sum_zero(n):
    """D applied to a constant is zero."""
    d = basis.derivative_matrix(n)
    np.testing.assert_allclose(d.sum(axis=1), 0.0, atol=1e-11)


@given(st.integers(min_value=2, max_value=20))
def test_derivative_matrix_negation_symmetry(n):
    """D[i,j] = -D[n-1-i, n-1-j] (parity of the GLL grid)."""
    d = basis.derivative_matrix(n)
    np.testing.assert_allclose(d, -d[::-1, ::-1], atol=1e-11)


def test_semhat_consistency():
    x, w, d = basis.semhat(10)
    np.testing.assert_allclose(x, basis.gll_points(10))
    np.testing.assert_allclose(w, basis.gll_weights(10))
    np.testing.assert_allclose(d, basis.derivative_matrix(10))


def test_n_too_small_raises():
    with pytest.raises(ValueError):
        basis.gll_points(1)


def test_legendre_known_values():
    x = np.array([-1.0, 0.0, 0.5, 1.0])
    np.testing.assert_allclose(basis.legendre(2, x), 0.5 * (3 * x**2 - 1))
    np.testing.assert_allclose(basis.legendre(3, x), 0.5 * (5 * x**3 - 3 * x))


@given(st.integers(min_value=1, max_value=12))
def test_legendre_deriv_matches_fd(order):
    x = np.linspace(-0.95, 0.95, 7)
    h = 1e-6
    fd = (basis.legendre(order, x + h) - basis.legendre(order, x - h)) / (2 * h)
    np.testing.assert_allclose(basis.legendre_deriv(order, x), fd, rtol=1e-6, atol=1e-6)


def test_legendre_deriv_endpoints():
    for order in range(1, 9):
        got = basis.legendre_deriv(order, np.array([-1.0, 1.0]))
        end = order * (order + 1) / 2
        np.testing.assert_allclose(got[1], end)
        np.testing.assert_allclose(got[0], end * (-1.0) ** (order - 1))
