"""Layer-2 model builder tests: spec validation, shapes, the fused CG
iteration executable."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import SharedCapacityError, ax_ref
from compile.model import AxSpec


def test_axspec_name():
    assert AxSpec("layered", 10, 64).name == "ax_layered_n10_e64"


def test_axspec_rejects_unknown_variant():
    with pytest.raises(KeyError):
        AxSpec("warp_speed", 10, 64).validate()


def test_axspec_rejects_bad_sizes():
    with pytest.raises(ValueError):
        AxSpec("layered", 1, 64).validate()
    with pytest.raises(ValueError):
        AxSpec("layered", 10, 0).validate()


def test_axspec_shared_capacity():
    AxSpec("shared", 10, 64).validate()  # fits
    with pytest.raises(SharedCapacityError):
        AxSpec("shared", 11, 64).validate()  # the paper's wall


def test_ax_arg_specs_shapes():
    u, d, g = model.ax_arg_specs(AxSpec("layered", 6, 8))
    assert u.shape == (8, 6, 6, 6)
    assert d.shape == (6, 6)
    assert g.shape == (8, 6, 6, 6, 6)


def test_make_ax_returns_one_tuple():
    spec = AxSpec("layered", 4, 2)
    fn = model.make_ax(spec)
    rng = np.random.default_rng(0)
    u = rng.standard_normal((2, 4, 4, 4))
    d = rng.standard_normal((4, 4))
    g = rng.standard_normal((2, 6, 4, 4, 4))
    out = fn(u, d, g)
    assert isinstance(out, tuple) and len(out) == 1
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(ax_ref(u, d, g)), rtol=1e-11)


def test_make_ax_is_jittable():
    spec = AxSpec("layered", 4, 2)
    fn = jax.jit(model.make_ax(spec))
    rng = np.random.default_rng(1)
    u = rng.standard_normal((2, 4, 4, 4))
    d = rng.standard_normal((4, 4))
    g = rng.standard_normal((2, 6, 4, 4, 4))
    np.testing.assert_allclose(
        np.asarray(fn(u, d, g)[0]), np.asarray(ax_ref(u, d, g)), rtol=1e-11, atol=1e-11
    )


def test_vector_arg_specs():
    specs = model.vector_arg_specs("glsc3", 100)
    assert len(specs) == 3 and all(s.shape == (100,) for s in specs)
    specs = model.vector_arg_specs("add2s1", 100)
    assert len(specs) == 3 and specs[2].shape == (1,)


def test_make_vector_op_unknown():
    with pytest.raises(KeyError):
        model.make_vector_op("daxpy", 10)


def test_cg_iter_fused_matches_unfused():
    """The perf-pass fused executable must compute exactly Ax + partial pap."""
    n, e = 4, 2
    fn = model.make_cg_iter("layered", n, e)
    rng = np.random.default_rng(7)
    p = rng.standard_normal((e, n, n, n))
    d = rng.standard_normal((n, n))
    g = rng.standard_normal((e, 6, n, n, n))
    c = rng.standard_normal((e, n, n, n))
    w, pap = fn(p, d, g, c)
    w_want = np.asarray(ax_ref(p, d, g))
    np.testing.assert_allclose(np.asarray(w), w_want, rtol=1e-11, atol=1e-11)
    np.testing.assert_allclose(np.asarray(pap)[0], np.sum(w_want * c * p), rtol=1e-11)


def test_cg_iter_arg_specs():
    specs = model.cg_iter_arg_specs(10, 64)
    assert [tuple(s.shape) for s in specs] == [
        (64, 10, 10, 10),
        (10, 10),
        (64, 6, 10, 10, 10),
        (64, 10, 10, 10),
    ]
