"""AOT pipeline tests: HLO text is emitted, well-formed, deterministic, and
the manifest describes it accurately."""

import json
import os

import jax
import numpy as np
import pytest

from compile import aot, model
from compile.model import AxSpec


def small_entries():
    spec = AxSpec("layered", 4, 2)
    return [
        dict(
            name=spec.name,
            kind="ax",
            variant="layered",
            n=4,
            chunk=2,
            dtype="float64",
            fn=model.make_ax(spec),
            args=model.ax_arg_specs(spec),
        ),
        dict(
            name="glsc3_s16",
            kind="vector",
            variant="glsc3",
            n=4,
            chunk=2,
            dtype="float64",
            fn=model.make_vector_op("glsc3", 16),
            args=model.vector_arg_specs("glsc3", 16),
        ),
    ]


def test_build_writes_artifacts_and_manifest(tmp_path):
    out = str(tmp_path)
    manifest = aot.build(out, small_entries(), verbose=False)
    assert len(manifest["artifacts"]) == 2
    for a in manifest["artifacts"]:
        path = os.path.join(out, a["file"])
        assert os.path.exists(path)
        text = open(path).read()
        assert text.startswith("HloModule")
        assert "ENTRY" in text
    on_disk = json.load(open(os.path.join(out, "manifest.json")))
    assert on_disk["artifacts"] == manifest["artifacts"]


def test_hlo_text_is_f64(tmp_path):
    manifest = aot.build(str(tmp_path), small_entries()[:1], verbose=False)
    text = open(os.path.join(str(tmp_path), manifest["artifacts"][0]["file"])).read()
    assert "f64" in text, "the paper computes in double precision"


def test_manifest_records_arg_shapes(tmp_path):
    manifest = aot.build(str(tmp_path), small_entries(), verbose=False)
    ax = manifest["artifacts"][0]
    assert ax["arg_shapes"] == [[2, 4, 4, 4], [4, 4], [2, 6, 4, 4, 4]]
    assert ax["num_args"] == 3


def test_lowering_deterministic():
    e = small_entries()[0]
    t1 = aot._lower(e["fn"], e["args"])
    t2 = aot._lower(e["fn"], e["args"])
    assert t1 == t2


def test_hlo_text_reparses():
    """The emitted text must survive a real HLO parser round-trip (the Rust
    loader depends on exactly this; the authoritative end-to-end check runs
    in rust/tests/ against xla_extension's parser + PJRT)."""
    from jax._src.lib import xla_client as xc

    e = small_entries()[0]
    text = aot._lower(e["fn"], e["args"])
    mod = xc._xla.hlo_module_from_text(text)
    rt = mod.to_string()
    # (u, d, g) -> (w,) with the spec's shapes survived the round-trip
    assert "f64[2,4,4,4]" in rt
    assert "f64[4,4]" in rt
    assert "f64[2,6,4,4,4]" in rt
    assert "ENTRY" in rt


def test_default_entries_cover_paper_versions():
    entries = aot.default_entries(extra_ns=(), perf_chunks=())
    names = {e["name"] for e in entries}
    for v in ("jnp", "original", "shared", "layered", "layered_unroll2"):
        assert f"ax_{v}_n10_e64" in names
    kinds = {e["kind"] for e in entries}
    assert kinds == {"ax", "vector", "cg_iter"}


def test_default_entries_shared_respects_wall():
    """default_entries must never emit a shared-variant artifact above the
    capacity wall."""
    entries = aot.default_entries(n=10)
    for e in entries:
        if e["variant"] == "shared":
            assert e["n"] <= 10


def test_tupled_flag_in_manifest(tmp_path):
    """Ax/vector artifacts lower with array roots (fast download); cg_iter
    keeps the tuple root (two outputs)."""
    entries = small_entries()
    entries.append(
        dict(
            name="cg_iter_layered_n4_e2",
            kind="cg_iter",
            variant="layered",
            n=4,
            chunk=2,
            dtype="float64",
            fn=model.make_cg_iter("layered", 4, 2),
            args=model.cg_iter_arg_specs(4, 2),
        )
    )
    manifest = aot.build(str(tmp_path), entries, verbose=False)
    by_name = {a["name"]: a for a in manifest["artifacts"]}
    assert by_name["ax_layered_n4_e2"]["tupled"] is False
    assert by_name["glsc3_s16"]["tupled"] is False
    assert by_name["cg_iter_layered_n4_e2"]["tupled"] is True
    # Root shape reflects it: array root has no top-level tuple.
    ax_text = open(os.path.join(str(tmp_path), "ax_layered_n4_e2.hlo.txt")).read()
    cg_text = open(os.path.join(str(tmp_path), "cg_iter_layered_n4_e2.hlo.txt")).read()
    assert ")->f64[2,4,4,4]" in ax_text, "ax root must be a bare array"
    assert ")->(f64[" in cg_text, "cg_iter root must stay a tuple"
