"""Shared test config: the paper computes in double precision, so x64 must
be enabled before any jax array is created."""

import jax

jax.config.update("jax_enable_x64", True)

from hypothesis import settings

# Interpret-mode Pallas is slow; keep the sweeps meaningful but bounded.
settings.register_profile("repro", max_examples=25, deadline=None)
settings.load_profile("repro")
