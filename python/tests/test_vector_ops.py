"""CG vector-op kernels vs their jnp references."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import vector_ops as vo


def rand_vec(rng, size, dtype):
    return rng.standard_normal(size).astype(dtype)


@given(
    size=st.integers(min_value=1, max_value=4096),
    seed=st.integers(min_value=0, max_value=2**31),
    dtype=st.sampled_from([np.float64, np.float32]),
)
@settings(max_examples=15, deadline=None)
def test_glsc3(size, seed, dtype):
    rng = np.random.default_rng(seed)
    a, b, m = (rand_vec(rng, size, dtype) for _ in range(3))
    got = np.asarray(vo.glsc3(jnp.asarray(a), jnp.asarray(b), jnp.asarray(m)))
    want = np.sum(a.astype(np.float64) * b * m)
    tol = 1e-3 if dtype == np.float32 else 1e-10
    np.testing.assert_allclose(got[0], want, rtol=tol, atol=tol)


@given(
    size=st.integers(min_value=1, max_value=4096),
    seed=st.integers(min_value=0, max_value=2**31),
    c=st.floats(min_value=-10, max_value=10, allow_nan=False),
)
@settings(max_examples=15, deadline=None)
def test_add2s1(size, seed, c):
    rng = np.random.default_rng(seed)
    a, b = rand_vec(rng, size, np.float64), rand_vec(rng, size, np.float64)
    got = np.asarray(vo.add2s1(jnp.asarray(a), jnp.asarray(b), jnp.asarray([c])))
    np.testing.assert_allclose(got, c * a + b, rtol=1e-12, atol=1e-12)


@given(
    size=st.integers(min_value=1, max_value=4096),
    seed=st.integers(min_value=0, max_value=2**31),
    c=st.floats(min_value=-10, max_value=10, allow_nan=False),
)
@settings(max_examples=15, deadline=None)
def test_add2s2(size, seed, c):
    rng = np.random.default_rng(seed)
    a, b = rand_vec(rng, size, np.float64), rand_vec(rng, size, np.float64)
    got = np.asarray(vo.add2s2(jnp.asarray(a), jnp.asarray(b), jnp.asarray([c])))
    np.testing.assert_allclose(got, a + c * b, rtol=1e-12, atol=1e-12)


def test_glsc3_zero_mult_masks_everything():
    a = np.ones(64)
    got = np.asarray(vo.glsc3(a, a, np.zeros(64)))
    assert got[0] == 0.0


def test_refs_consistent():
    rng = np.random.default_rng(0)
    a, b, m = (rng.standard_normal(100) for _ in range(3))
    np.testing.assert_allclose(np.asarray(vo.glsc3_ref(a, b, m)), np.sum(a * b * m))
    np.testing.assert_allclose(np.asarray(vo.add2s1_ref(a, b, 2.0)), 2 * a + b)
    np.testing.assert_allclose(np.asarray(vo.add2s2_ref(a, b, 2.0)), a + 2 * b)
