"""Build-time Python package for nekbone-rs: JAX/Pallas kernels, the L2
compute graph, and the AOT lowering pipeline. Never imported at runtime."""
