"""Spectral-element basis utilities: Gauss-Lobatto-Legendre (GLL) points,
quadrature weights and the pseudo-spectral differentiation matrix.

This is the python twin of ``rust/src/basis`` (Nekbone's ``semhat``). The two
implementations are cross-checked in the test suites: both must agree to
machine precision, since the Rust coordinator generates the operator inputs
that the AOT-compiled kernels consume.

All routines are plain numpy (build-time only; nothing here runs on the
request path).
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "legendre",
    "legendre_deriv",
    "gll_points",
    "gll_weights",
    "derivative_matrix",
    "semhat",
]


def legendre(order: int, x: np.ndarray) -> np.ndarray:
    """Evaluate the Legendre polynomial P_order at ``x``.

    Uses the three-term Bonnet recurrence
    ``(m+1) P_{m+1}(x) = (2m+1) x P_m(x) - m P_{m-1}(x)``,
    which is numerically stable on [-1, 1].
    """
    x = np.asarray(x, dtype=np.float64)
    if order == 0:
        return np.ones_like(x)
    if order == 1:
        return x.copy()
    p_prev = np.ones_like(x)
    p = x.copy()
    for m in range(1, order):
        p_next = ((2 * m + 1) * x * p - m * p_prev) / (m + 1)
        p_prev, p = p, p_next
    return p


def legendre_deriv(order: int, x: np.ndarray) -> np.ndarray:
    """Evaluate d/dx P_order(x) via the standard derivative relation
    ``(x^2 - 1)/order * P'_order = x P_order - P_{order-1}`` away from the
    endpoints, with the closed-form endpoint limit
    ``P'_order(±1) = (±1)^{order-1} order (order+1) / 2``.
    """
    x = np.asarray(x, dtype=np.float64)
    if order == 0:
        return np.zeros_like(x)
    pn = legendre(order, x)
    pnm1 = legendre(order - 1, x)
    out = np.empty_like(x)
    interior = np.abs(np.abs(x) - 1.0) > 1e-13
    xi = x[interior]
    out[interior] = order * (xi * pn[interior] - pnm1[interior]) / (xi * xi - 1.0)
    edge = ~interior
    sign = np.where(x[edge] > 0, 1.0, np.where(order % 2 == 0, -1.0, 1.0))
    out[edge] = sign * order * (order + 1) / 2.0
    return out


def gll_points(n: int) -> np.ndarray:
    """The ``n`` Gauss-Lobatto-Legendre points on [-1, 1].

    ``n = polynomial degree + 1``. The points are the endpoints ±1 plus the
    roots of P'_{n-1}; interior roots are found with Newton iteration from
    the Chebyshev-Gauss-Lobatto initial guess (standard approach, converges
    quadratically, < 10 iterations to 1e-15 for n <= 64).
    """
    if n < 2:
        raise ValueError(f"GLL needs at least 2 points, got n={n}")
    order = n - 1
    # Chebyshev-Gauss-Lobatto initial guess.
    x = -np.cos(np.pi * np.arange(n) / order)
    # Newton on q(x) = P'_order(x) for the interior nodes. We use the
    # recurrence-free formulation from the classic Matlab `lglnodes`:
    # iterate on x -= (x P_order - P_{order-1}) / (n P_order), which has the
    # GLL points (including the endpoints) as fixed points.
    x_old = np.full_like(x, 2.0)
    it = 0
    while np.max(np.abs(x - x_old)) > 1e-15 and it < 100:
        x_old = x.copy()
        pn = legendre(order, x)
        pnm1 = legendre(order - 1, x)
        x = x_old - (x_old * pn - pnm1) / (n * pn)
        it += 1
    x[0], x[-1] = -1.0, 1.0
    return x


def gll_weights(n: int) -> np.ndarray:
    """GLL quadrature weights ``w_j = 2 / (order (order+1) P_order(x_j)^2)``
    with ``order = n - 1``. Exact for polynomials of degree <= 2n - 3.
    """
    order = n - 1
    x = gll_points(n)
    pn = legendre(order, x)
    return 2.0 / (order * (order + 1) * pn * pn)


def derivative_matrix(n: int) -> np.ndarray:
    """The GLL pseudo-spectral differentiation matrix D (Nekbone's ``dxm1``).

    ``(D u)_i = sum_j D[i, j] u_j`` is the derivative of the degree-(n-1)
    interpolant of ``u`` evaluated at GLL node i. Closed form
    (e.g. Canuto et al., Spectral Methods):

        D[i, j] = P(x_i) / (P(x_j) (x_i - x_j))       i != j
        D[0, 0] = -order (order + 1) / 4
        D[order, order] = +order (order + 1) / 4
        D[i, i] = 0                                    otherwise

    with ``P = P_order``, ``order = n - 1``.
    """
    order = n - 1
    x = gll_points(n)
    pn = legendre(order, x)
    d = np.zeros((n, n), dtype=np.float64)
    for i in range(n):
        for j in range(n):
            if i != j:
                d[i, j] = pn[i] / (pn[j] * (x[i] - x[j]))
    d[0, 0] = -order * (order + 1) / 4.0
    d[order, order] = order * (order + 1) / 4.0
    return d


def semhat(n: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Nekbone's ``semhat``: (points, weights, derivative matrix) for n GLL
    nodes. Returned in that order."""
    return gll_points(n), gll_weights(n), derivative_matrix(n)
