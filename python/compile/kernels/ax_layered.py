"""The paper's contribution: the *2D thread structure* / layered kernel,
re-thought for TPU as a Pallas kernel (paper section IV-C, Fig. 1).

CUDA original: one thread block per element holds an ``n x n`` layer of
threads that sweeps the ``k`` layers in lock-step. Per layer it

  1. stages the layer ``u(:,:,k)`` into shared memory (sync #1),
  2. computes the r/s derivatives from the shared layer and the t derivative
     from a per-thread register column ``u(i,j,:)``,
  3. applies the geometric factors,
  4. stages ``ur/us`` back to shared memory (sync #2) and computes the r/s
     part of the divergence for layer k, while scattering the t part
     ``D[k,m] * ut_k`` into a per-thread register accumulator ``rw[m]``.

TPU mapping (see DESIGN.md section "Hardware-Adaptation"):

  * concurrent thread blocks -> the batched element axis of the block
    (one launch processes the whole chunk; each element's schedule is
    independent, exactly like co-resident CUDA blocks),
  * shared memory       -> VMEM-staged block values (D, and the (E,n,n)
    layer tiles produced inside the k sweep),
  * register column / accumulator -> the per-layer ``rw`` values carried
    through the unrolled sweep,
  * warp-synchronous r/s contractions -> two batched (n,n) matmuls per
    layer (``u_k @ D^T`` and ``D @ u_k``) - MXU-shaped,
  * ``#pragma unroll``  -> the k sweep is unrolled at trace time (the
    paper's CUDA-C compiler unrolling); ``ax_layered_unroll2`` instead
    keeps a run-time ``fori_loop`` manually unrolled by two (the paper's
    CUDA-Fortran variant, which unrolls by hand once),
  * __syncthreads x2    -> the dataflow ordering between the stage-1 layer
    products and the stage-2 accumulation.

Fast-memory pressure per element is ``O(n^3) + O(n^2)`` (the u block plus
layer tiles) instead of the shared variant's ``5 n^3``; nothing but block
constants changes with the polynomial degree - the paper's "ported to
other polynomial degrees by changing a few constants".
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["ax_layered", "ax_layered_unroll2"]


def _layer_terms(u_k, wt, g_k, d, dt):
    """Stage-1 products and geometric factors for one k layer.

    ``u_k``: (E, n, n) layer tile; ``wt``: (E, n, n) t-derivative of this
    layer; ``g_k``: (E, 6, n, n). Returns ``(w_rs, ut)``: the r/s part of
    the divergence landing in this layer, and the ut tile to scatter.
    """
    # wr[e,j,i] = sum_l d[i,l] u_k[e,j,l]  ->  u_k @ d^T (batched matmul)
    wr = u_k @ dt
    # ws[e,j,i] = sum_l d[j,l] u_k[e,l,i]  ->  d @ u_k (batched matmul)
    ws = jnp.einsum("jl,eli->eji", d, u_k)
    ur = g_k[:, 0] * wr + g_k[:, 1] * ws + g_k[:, 2] * wt
    us = g_k[:, 1] * wr + g_k[:, 3] * ws + g_k[:, 4] * wt
    ut = g_k[:, 2] * wr + g_k[:, 4] * ws + g_k[:, 5] * wt
    # Stage-2 r/s parts: w_rs[e,j,i] = sum_l d[l,i] ur[e,j,l]
    #                               + sum_l d[l,j] us[e,l,i]
    w_rs = ur @ d + jnp.einsum("lj,eli->eji", d, us)
    return w_rs, ut


def _unrolled_kernel(d_ref, u_ref, g_ref, w_ref):
    """k sweep unrolled at trace time (#pragma unroll analog)."""
    n = d_ref.shape[0]
    d = d_ref[...]  # D resident in VMEM for the whole launch
    dt = d.T        # D^T formed once (dxtm1)
    u = u_ref[...]  # (E, n, n, n)
    g = g_ref[...]  # (E, 6, n, n, n)
    # The t-derivative comes from the CUDA kernel's *register column*: each
    # thread holds u(i,j,:) in registers, so u is read exactly once for the
    # whole t contraction. The batched analog is one contraction over the
    # layer axis (reading u once), then per-layer slices of the result.
    wt_all = jnp.einsum("kl,elji->ekji", d, u)
    layers = []
    for k in range(n):
        u_k = u[:, k]  # staged layer tile (sync #1)
        w_rs, ut = _layer_terms(u_k, wt_all[:, k], g[:, :, k], d, dt)
        layers.append((w_rs, ut))
    # Scatter: w[e,m,j,i] = w_rs[m] + sum_k d[k,m] ut_k  (register rw[m]).
    ut_stack = jnp.stack([ut for (_, ut) in layers], axis=1)  # (E, n, n, n)
    w_rs_stack = jnp.stack([w_rs for (w_rs, _) in layers], axis=1)
    w_ref[...] = w_rs_stack + jnp.einsum("km,ekji->emji", d, ut_stack)


def _looped_kernel(d_ref, u_ref, g_ref, w_ref, *, unroll: int):
    """Run-time k loop, manually unrolled by `unroll` (CUDA-Fortran
    analog: 'the loop was manually unrolled once instead')."""
    n = d_ref.shape[0]
    d = d_ref[...]
    dt = d.T
    u = u_ref[...]
    g = g_ref[...]
    nelt = u.shape[0]

    # Register-column t-derivative, u read once (see _unrolled_kernel).
    wt_all = jnp.einsum("kl,elji->ekji", d, u)

    def step(k, rw):
        u_k = jax.lax.dynamic_index_in_dim(u, k, axis=1, keepdims=False)
        d_k = jax.lax.dynamic_index_in_dim(d, k, axis=0, keepdims=False)
        wt = jax.lax.dynamic_index_in_dim(wt_all, k, axis=1, keepdims=False)
        g_k = jax.lax.dynamic_index_in_dim(g, k, axis=2, keepdims=False)
        w_rs, ut = _layer_terms(u_k, wt, g_k, d, dt)
        rw = rw + d_k[None, :, None, None] * ut[:, None]
        prev = jax.lax.dynamic_index_in_dim(rw, k, axis=1, keepdims=False)
        return jax.lax.dynamic_update_index_in_dim(rw, prev + w_rs, k, axis=1)

    def body(t, rw):
        k0 = t * unroll
        for off in range(unroll):
            rw = step(k0 + off, rw)
        return rw

    rw0 = jnp.zeros((nelt, n, n, n), u.dtype)
    trips, rem = divmod(n, unroll)
    rw = jax.lax.fori_loop(0, trips, body, rw0)
    for k in range(n - rem, n):  # peeled remainder layers
        rw = step(k, rw)
    w_ref[...] = rw


def _call(kernel, u, d, g):
    nelt, n = u.shape[0], u.shape[1]
    (w,) = pl.pallas_call(
        kernel,
        out_shape=[jax.ShapeDtypeStruct((nelt, n, n, n), u.dtype)],
        interpret=True,
    )(d, u, g)
    return w


def ax_layered(u: jnp.ndarray, d: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    """Local Poisson operator with the paper's layered schedule, k sweep
    unrolled at trace time (the optimized CUDA-C kernel)."""
    return _call(_unrolled_kernel, u, d, g)


def ax_layered_unroll2(u: jnp.ndarray, d: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    """Layered schedule with a run-time k loop manually unrolled by two
    (the optimized CUDA-Fortran kernel)."""
    return _call(functools.partial(_looped_kernel, unroll=2), u, d, g)
