"""Pallas kernels for Nekbone's CG vector operations.

In the paper these "simpler vector operations" run under OpenACC on the GPU
(section IV); in this reproduction they run natively in the Rust coordinator
by default, with these Pallas/XLA versions selectable via
``--vector-backend xla``. Benchmark E6 (``cargo bench --bench ablations --
vector-backend``) reproduces the paper's claim that moving the simple ops to
the compiler-scheduled path costs only a few percent.

Nekbone names (cg.f):

    glsc3(a, b, mult)      weighted inner product  sum_i a_i b_i mult_i
    add2s1(a, b, c1)       a <- c1 * a + b
    add2s2(a, b, c2)       a <- a + c2 * b

All kernels operate on flat f64 vectors of a fixed chunk length; the
coordinator reduces partial ``glsc3`` results across chunks and ranks.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["glsc3", "add2s1", "add2s2", "glsc3_ref", "add2s1_ref", "add2s2_ref"]


# ---------------------------------------------------------------- references
def glsc3_ref(a, b, mult):
    return jnp.sum(a * b * mult)


def add2s1_ref(a, b, c1):
    return c1 * a + b


def add2s2_ref(a, b, c2):
    return a + c2 * b


# ------------------------------------------------------------------ kernels
def _glsc3_kernel(a_ref, b_ref, m_ref, o_ref):
    o_ref[0] = jnp.sum(a_ref[...] * b_ref[...] * m_ref[...])


def glsc3(a: jnp.ndarray, b: jnp.ndarray, mult: jnp.ndarray) -> jnp.ndarray:
    """Weighted inner product over one chunk; returns a scalar in a (1,)
    array (PJRT outputs are tensors)."""
    (size,) = a.shape
    (out,) = pl.pallas_call(
        _glsc3_kernel,
        out_shape=[jax.ShapeDtypeStruct((1,), a.dtype)],
        interpret=True,
    )(a, b, mult)
    return out


def _add2s1_kernel(a_ref, b_ref, c_ref, o_ref):
    o_ref[...] = c_ref[0] * a_ref[...] + b_ref[...]


def add2s1(a: jnp.ndarray, b: jnp.ndarray, c1: jnp.ndarray) -> jnp.ndarray:
    """``c1 * a + b`` elementwise; ``c1`` is a (1,) array."""
    (out,) = pl.pallas_call(
        _add2s1_kernel,
        out_shape=[jax.ShapeDtypeStruct(a.shape, a.dtype)],
        interpret=True,
    )(a, b, c1)
    return out


def _add2s2_kernel(a_ref, b_ref, c_ref, o_ref):
    o_ref[...] = a_ref[...] + c_ref[0] * b_ref[...]


def add2s2(a: jnp.ndarray, b: jnp.ndarray, c2: jnp.ndarray) -> jnp.ndarray:
    """``a + c2 * b`` elementwise; ``c2`` is a (1,) array."""
    (out,) = pl.pallas_call(
        _add2s2_kernel,
        out_shape=[jax.ShapeDtypeStruct(a.shape, a.dtype)],
        interpret=True,
    )(a, b, c2)
    return out
