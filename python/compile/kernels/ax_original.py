"""Pallas analog of the *original* GPU Nekbone kernel (Gong et al. [11]).

Paper section IV-A: the original CUDA-Fortran/OpenACC implementation keeps
everything in global memory and has poor temporal locality - the stage-1
gradients ``ur/us/ut`` are materialized to global memory and read back by a
second kernel.

We mirror that structure exactly: **two** ``pallas_call`` launches with the
three intermediate fields round-tripping through HBM (the "global memory" of
the TPU mapping). Within each launch the computation is expressed as
whole-volume contractions with no layering or staging discipline - the
analog of "as many threads as possible, not organized for locality". The
chunk's element axis is batched inside the launch (concurrent thread
blocks).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["ax_original"]


def _stage1_kernel(d_ref, u_ref, g_ref, ur_ref, us_ref, ut_ref):
    """Gradient + geometric factors; writes ur/us/ut back to HBM."""
    d = d_ref[...]
    u = u_ref[...]  # (E, n, n, n) axes (e, k, j, i)
    wr = jnp.einsum("il,ekjl->ekji", d, u)
    ws = jnp.einsum("jl,ekli->ekji", d, u)
    wt = jnp.einsum("kl,elji->ekji", d, u)
    g = g_ref[...]  # (E, 6, n, n, n)
    ur_ref[...] = g[:, 0] * wr + g[:, 1] * ws + g[:, 2] * wt
    us_ref[...] = g[:, 1] * wr + g[:, 3] * ws + g[:, 4] * wt
    ut_ref[...] = g[:, 2] * wr + g[:, 4] * ws + g[:, 5] * wt


def _stage2_kernel(d_ref, ur_ref, us_ref, ut_ref, w_ref):
    """Divergence stage; reads ur/us/ut back from HBM."""
    d = d_ref[...]
    ur, us, ut = ur_ref[...], us_ref[...], ut_ref[...]
    w_ref[...] = (
        jnp.einsum("li,ekjl->ekji", d, ur)
        + jnp.einsum("lj,ekli->ekji", d, us)
        + jnp.einsum("lk,elji->ekji", d, ut)
    )


def ax_original(u: jnp.ndarray, d: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    """Local Poisson operator, original-GPU-kernel structure.

    Shapes: ``u [E,n,n,n]``, ``d [n,n]``, ``g [E,6,n,n,n]`` -> ``w [E,n,n,n]``.
    """
    nelt, n = u.shape[0], u.shape[1]
    elem = jax.ShapeDtypeStruct((nelt, n, n, n), u.dtype)

    ur, us, ut = pl.pallas_call(
        _stage1_kernel,
        out_shape=[elem, elem, elem],
        interpret=True,
    )(d, u, g)

    (w,) = pl.pallas_call(
        _stage2_kernel,
        out_shape=[elem],
        interpret=True,
    )(d, ur, us, ut)
    return w
