"""Layer-1 Pallas kernels for the Nekbone local Poisson operator.

Variant registry (the paper's five GPU versions, section IV):

    "jnp"              pure-jnp einsum, compiler-scheduled (OpenACC analog)
    "original"         two launches, intermediates round-trip HBM (Gong et al.)
    "shared"           whole element staged to VMEM, capacity-bound (Jocksch et al.)
    "layered"          the paper's 2D-thread-structure schedule (CUDA C)
    "layered_unroll2"  layered with k loop manually unrolled x2 (CUDA Fortran)
"""

from .ref import ax_ref, grad_ref, gather_grad
from .ax_original import ax_original
from .ax_shared import ax_shared, shared_bytes, SharedCapacityError, SHARED_BUDGET_BYTES
from .ax_layered import ax_layered, ax_layered_unroll2
from . import vector_ops

#: variant name -> callable(u, d, g) -> w
AX_VARIANTS = {
    "jnp": ax_ref,
    "original": ax_original,
    "shared": ax_shared,
    "layered": ax_layered,
    "layered_unroll2": ax_layered_unroll2,
}

__all__ = [
    "AX_VARIANTS",
    "ax_ref",
    "grad_ref",
    "gather_grad",
    "ax_original",
    "ax_shared",
    "ax_layered",
    "ax_layered_unroll2",
    "shared_bytes",
    "SharedCapacityError",
    "SHARED_BUDGET_BYTES",
    "vector_ops",
]
