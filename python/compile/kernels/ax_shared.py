"""Pallas analog of the *shared-memory* GPU Nekbone kernel (Jocksch et al.).

Paper section IV-B: the whole element (nodal values + the differentiation
matrix + the three gradient intermediates) is staged into GPU shared memory
and the computation runs as in the original approach, but against fast
memory. The approach is **capacity-bound**: "for a P100 GPU this approach
does not work for elements with more than 10 GLL points".

TPU mapping: the element block and all three intermediates are staged into
VMEM inside a *single* grid step (no HBM round-trip, unlike
:mod:`ax_original`), still with no layering. We enforce the paper's capacity
wall explicitly with a shared-memory budget modeled on the P100's 64 KiB/SM
(48 KiB usable per block): the variant refuses to build when

    bytes(u) + bytes(ur) + bytes(us) + bytes(ut) + bytes(w) + 2 bytes(D)
      = (5 n^3 + 2 n^2) * 8  >  budget

which for f64 fails exactly above n = 10 - the same wall as the paper
(n=10: 41.6 KiB fits; n=11: 55.1 KiB does not).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["ax_shared", "shared_bytes", "SHARED_BUDGET_BYTES", "SharedCapacityError"]

#: Usable shared memory per thread block on the P100 (the paper's capacity
#: wall). 48 KiB: 64 KiB/SM minus the L1-carveout granularity.
SHARED_BUDGET_BYTES = 48 * 1024


class SharedCapacityError(ValueError):
    """Raised when an element does not fit the shared-memory budget."""


def shared_bytes(n: int, itemsize: int = 8) -> int:
    """Bytes of fast memory the shared-memory schedule needs per element:
    u + ur + us + ut + the w accumulator (5 n^3 values) plus D and D^T
    (2 n^2 values)."""
    return (5 * n**3 + 2 * n**2) * itemsize


def _kernel(d_ref, u_ref, g_ref, w_ref):
    # Everything below operates on VMEM-staged values: u, D, and the three
    # full-size gradient intermediates live in fast memory for the whole
    # launch (one call, no HBM round-trip - unlike ax_original). The element
    # axis is batched (concurrent thread blocks); the capacity wall is
    # per element, matching per-block shared memory.
    d = d_ref[...]
    u = u_ref[...]  # (E, n, n, n)
    g = g_ref[...]  # (E, 6, n, n, n)
    wr = jnp.einsum("il,ekjl->ekji", d, u)
    ws = jnp.einsum("jl,ekli->ekji", d, u)
    wt = jnp.einsum("kl,elji->ekji", d, u)
    ur = g[:, 0] * wr + g[:, 1] * ws + g[:, 2] * wt
    us = g[:, 1] * wr + g[:, 3] * ws + g[:, 4] * wt
    ut = g[:, 2] * wr + g[:, 4] * ws + g[:, 5] * wt
    w_ref[...] = (
        jnp.einsum("li,ekjl->ekji", d, ur)
        + jnp.einsum("lj,ekli->ekji", d, us)
        + jnp.einsum("lk,elji->ekji", d, ut)
    )


def ax_shared(u: jnp.ndarray, d: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    """Local Poisson operator, shared-memory-kernel structure.

    Raises :class:`SharedCapacityError` when the element exceeds the
    shared-memory budget (n > 10 for f64), mirroring the paper's limitation.
    """
    nelt, n = u.shape[0], u.shape[1]
    itemsize = jnp.dtype(u.dtype).itemsize
    need = shared_bytes(n, itemsize)
    if need > SHARED_BUDGET_BYTES:
        raise SharedCapacityError(
            f"shared-memory schedule needs {need} B for n={n} "
            f"(> budget {SHARED_BUDGET_BYTES} B); the paper's P100 wall is "
            f"n > 10 - use the layered variant instead"
        )
    (w,) = pl.pallas_call(
        _kernel,
        out_shape=[jax.ShapeDtypeStruct((nelt, n, n, n), u.dtype)],
        interpret=True,
    )(d, u, g)
    return w
