"""Pure-jnp oracle for the Nekbone local Poisson operator (paper Listing 1).

This module is the single source of truth for *what* the operator computes;
every Pallas variant in this package is tested against it. It also doubles as
the "OpenACC" analog of the paper (section IV): a compiler-scheduled
formulation with no hand-written data staging, lowered to its own HLO
artifact (variant name ``jnp``).

Array convention (shared with the Rust side):

    u  f64[E, n, n, n]     axes (element, k, j, i) - i fastest, matching the
                           memory order of Fortran ``u(i,j,k,e)``
    d  f64[n, n]           dxm1: (D u)_i = sum_l d[i, l] u_l
    g  f64[E, 6, n, n, n]  geometric factors G1..G6 (the symmetric 3x3 per
                           gridpoint, upper-triangular storage:
                           [G11, G12, G13, G22, G23, G33])
    w  f64[E, n, n, n]     output

The operator (paper Listing 1, two tensor-contraction stages):

    wr(i,j,k) = sum_l d[i,l] u(l,j,k)
    ws(i,j,k) = sum_l d[j,l] u(i,l,k)
    wt(i,j,k) = sum_l d[k,l] u(i,j,l)
    ur = G11 wr + G12 ws + G13 wt
    us = G12 wr + G22 ws + G23 wt
    ut = G13 wr + G23 ws + G33 wt
    w(i,j,k)  = sum_l d[l,i] ur(l,j,k) + d[l,j] us(i,l,k) + d[l,k] ut(i,j,l)
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["grad_ref", "gather_grad", "ax_ref"]


def grad_ref(u: jnp.ndarray, d: jnp.ndarray):
    """Stage 1: local r/s/t derivatives of ``u`` on every element.

    Returns ``(wr, ws, wt)``, each shaped like ``u``.
    """
    # wr[e,k,j,i] = sum_l d[i,l] u[e,k,j,l]
    wr = jnp.einsum("il,ekjl->ekji", d, u)
    # ws[e,k,j,i] = sum_l d[j,l] u[e,k,l,i]
    ws = jnp.einsum("jl,ekli->ekji", d, u)
    # wt[e,k,j,i] = sum_l d[k,l] u[e,l,j,i]
    wt = jnp.einsum("kl,elji->ekji", d, u)
    return wr, ws, wt


def gather_grad(wr, ws, wt, g):
    """Apply the symmetric geometric-factor tensor to the local gradient."""
    g11, g12, g13 = g[:, 0], g[:, 1], g[:, 2]
    g22, g23, g33 = g[:, 3], g[:, 4], g[:, 5]
    ur = g11 * wr + g12 * ws + g13 * wt
    us = g12 * wr + g22 * ws + g23 * wt
    ut = g13 * wr + g23 * ws + g33 * wt
    return ur, us, ut


def ax_ref(u: jnp.ndarray, d: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    """The full local Poisson operator ``w = A_local u`` (paper Listing 1)."""
    wr, ws, wt = grad_ref(u, d)
    ur, us, ut = gather_grad(wr, ws, wt, g)
    # Stage 2 uses the transpose contractions (dxtm1 in Nekbone):
    # w[e,k,j,i] = sum_l d[l,i] ur[e,k,j,l]
    #            + sum_l d[l,j] us[e,k,l,i]
    #            + sum_l d[l,k] ut[e,l,j,i]
    w = (
        jnp.einsum("li,ekjl->ekji", d, ur)
        + jnp.einsum("lj,ekli->ekji", d, us)
        + jnp.einsum("lk,elji->ekji", d, ut)
    )
    return w
