"""Layer 2: the JAX compute graph around the Layer-1 kernels.

The "model" of this systems paper is the Nekbone Ax operator plus the CG
vector algebra. This module builds the concrete jittable callables that
``aot.py`` lowers to HLO text, each specialized to a fixed
``(variant, n, chunk, dtype)`` - the GPU analog of compiling one kernel
per launch configuration.

Nothing in this package runs at serve time: the Rust coordinator loads the
lowered artifacts through PJRT and feeds them buffers.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels import AX_VARIANTS, SharedCapacityError, shared_bytes, SHARED_BUDGET_BYTES
from .kernels import vector_ops

__all__ = [
    "AxSpec",
    "make_ax",
    "ax_arg_specs",
    "make_vector_op",
    "vector_arg_specs",
    "make_cg_iter",
    "cg_iter_arg_specs",
]


@dataclass(frozen=True)
class AxSpec:
    """Static configuration of one Ax executable."""

    variant: str
    n: int
    chunk: int
    dtype: str = "float64"

    @property
    def name(self) -> str:
        return f"ax_{self.variant}_n{self.n}_e{self.chunk}"

    def validate(self) -> None:
        if self.variant not in AX_VARIANTS:
            raise KeyError(f"unknown Ax variant {self.variant!r}")
        if self.n < 2:
            raise ValueError(f"n must be >= 2, got {self.n}")
        if self.chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {self.chunk}")
        itemsize = jnp.dtype(self.dtype).itemsize
        if self.variant == "shared" and shared_bytes(self.n, itemsize) > SHARED_BUDGET_BYTES:
            raise SharedCapacityError(
                f"variant 'shared' cannot build n={self.n} (paper's capacity wall)"
            )


def ax_arg_specs(spec: AxSpec):
    """ShapeDtypeStructs for (u, d, g) of one Ax executable."""
    n, e, dt = spec.n, spec.chunk, spec.dtype
    return (
        jax.ShapeDtypeStruct((e, n, n, n), dt),
        jax.ShapeDtypeStruct((n, n), dt),
        jax.ShapeDtypeStruct((e, 6, n, n, n), dt),
    )


def make_ax(spec: AxSpec):
    """Return the jittable ``(u, d, g) -> (w,)`` for one configuration.

    The 1-tuple return matches ``return_tuple=True`` lowering, which the
    Rust loader unwraps with ``to_tuple1``.
    """
    spec.validate()
    fn = AX_VARIANTS[spec.variant]

    def ax(u, d, g):
        return (fn(u, d, g),)

    return ax


# ------------------------------------------------------------- vector ops
_VECTOR_OPS = {
    # name -> (builder, n_vector_args, n_scalar_args)
    "glsc3": (vector_ops.glsc3, 3, 0),
    "add2s1": (vector_ops.add2s1, 2, 1),
    "add2s2": (vector_ops.add2s2, 2, 1),
}


def vector_arg_specs(op: str, size: int, dtype: str = "float64"):
    builder, nvec, nscal = _VECTOR_OPS[op]
    vecs = tuple(jax.ShapeDtypeStruct((size,), dtype) for _ in range(nvec))
    scals = tuple(jax.ShapeDtypeStruct((1,), dtype) for _ in range(nscal))
    return vecs + scals


def make_vector_op(op: str, size: int, dtype: str = "float64"):
    """Jittable chunk-sized vector op ``(vectors..., scalars...) -> (out,)``."""
    if op not in _VECTOR_OPS:
        raise KeyError(f"unknown vector op {op!r}")
    builder, _, _ = _VECTOR_OPS[op]

    def f(*args):
        return (builder(*args),)

    return f


# -------------------------------------------------- fused CG inner update
def cg_iter_arg_specs(n: int, chunk: int, dtype: str = "float64"):
    """(p, d, g, c) for the fused per-chunk CG compute: Ax + local pap."""
    e = chunk
    return (
        jax.ShapeDtypeStruct((e, n, n, n), dtype),
        jax.ShapeDtypeStruct((n, n), dtype),
        jax.ShapeDtypeStruct((e, 6, n, n, n), dtype),
        jax.ShapeDtypeStruct((e, n, n, n), dtype),
    )


def make_cg_iter(variant: str, n: int, chunk: int, dtype: str = "float64"):
    """Fused hot-path executable: ``w = Ax(p)`` plus the chunk's partial
    ``pap = sum w * c * p`` in one launch (perf-pass artifact - saves one
    HBM round-trip of ``w`` per CG iteration)."""
    spec = AxSpec(variant, n, chunk, dtype)
    spec.validate()
    fn = AX_VARIANTS[variant]

    def f(p, d, g, c):
        w = fn(p, d, g)
        pap = jnp.sum(w * c * p).reshape((1,))
        return (w, pap)

    return f
