"""AOT pipeline: lower every Layer-2 callable to HLO **text** + a JSON
manifest the Rust runtime consumes.

Why text, not ``.serialize()``: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids, which the xla_extension 0.5.1 behind the ``xla`` crate
rejects (``proto.id() <= INT_MAX``). The HLO text parser reassigns ids, so
text round-trips cleanly (see /opt/xla-example/README.md).

Run once at build time (``make artifacts``)::

    cd python && python -m compile.aot --out ../artifacts

Python never runs on the request path; after this, the Rust binary is
self-contained.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import time

import jax

jax.config.update("jax_enable_x64", True)  # the paper computes in f64

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402
from .kernels import shared_bytes, SHARED_BUDGET_BYTES  # noqa: E402
from .model import AxSpec  # noqa: E402


def shared_fits(n: int, itemsize: int = 8) -> bool:
    """Whether the shared-memory schedule fits the capacity wall at n."""
    return shared_bytes(n, itemsize) <= SHARED_BUDGET_BYTES

__all__ = ["to_hlo_text", "default_entries", "build", "main"]

#: Default chunk size (elements per launch). All paper sweeps (64..4096 and
#: 448..3584) are multiples of 64; see DESIGN.md section 6.
DEFAULT_CHUNK = 64

#: Default GLL points per dimension: the paper runs polynomial degree 9.
DEFAULT_N = 10

#: Ax variants lowered by default (all five of the paper's GPU versions).
DEFAULT_VARIANTS = ("jnp", "original", "shared", "layered", "layered_unroll2")


def to_hlo_text(lowered, return_tuple: bool = True) -> str:
    """jax Lowered -> XLA HLO text (the interchange format).

    ``return_tuple=False`` gives single-output computations an array root,
    letting the Rust side ``copy_raw_to_host_sync`` straight out of the
    output buffer with no intermediate Literal (perf pass, EXPERIMENTS.md
    §Perf L3). Multi-output computations keep the tuple root.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=return_tuple
    )
    return comp.as_hlo_text()


def _lower(fn, arg_specs, return_tuple: bool = True) -> str:
    return to_hlo_text(jax.jit(fn).lower(*arg_specs), return_tuple)


def default_entries(
    n: int = DEFAULT_N,
    chunk: int = DEFAULT_CHUNK,
    variants=DEFAULT_VARIANTS,
    extra_ns=(8, 12),
    perf_chunks=(256, 1024),
    dtype: str = "float64",
):
    """The artifact set: (name, kind, metadata, builder, arg_specs) tuples.

    * every Ax variant at the paper's configuration (n=10, chunk=64);
    * the layered variant additionally at other polynomial degrees (the
      paper's "changing a few constants" portability claim, experiment E7)
      and at larger chunks (perf pass, dispatch-overhead amortization);
    * chunk-sized CG vector ops (the "OpenACC" ablation, E6);
    * the fused Ax+pap hot-path executable (perf pass).
    """
    entries = []

    def add_ax(variant, nn, ee):
        spec = AxSpec(variant, nn, ee, dtype)
        entries.append(
            dict(
                name=spec.name,
                kind="ax",
                variant=variant,
                n=nn,
                chunk=ee,
                dtype=dtype,
                fn=model.make_ax(spec),
                args=model.ax_arg_specs(spec),
            )
        )

    for v in variants:
        add_ax(v, n, chunk)
    for nn in extra_ns:
        if nn != n:
            add_ax("layered", nn, chunk)
            # The shared variant exists wherever it fits under the paper's
            # capacity wall (E7 compares the two below the wall).
            if "shared" in variants and shared_fits(nn):
                add_ax("shared", nn, chunk)
    for ee in perf_chunks:
        add_ax("layered", n, ee)

    size = chunk * n * n * n
    for op in ("glsc3", "add2s1", "add2s2"):
        entries.append(
            dict(
                name=f"{op}_s{size}",
                kind="vector",
                variant=op,
                n=n,
                chunk=chunk,
                dtype=dtype,
                fn=model.make_vector_op(op, size, dtype),
                args=model.vector_arg_specs(op, size, dtype),
            )
        )

    for ee in (chunk,) + tuple(perf_chunks):
        entries.append(
            dict(
                name=f"cg_iter_layered_n{n}_e{ee}",
                kind="cg_iter",
                variant="layered",
                n=n,
                chunk=ee,
                dtype=dtype,
                fn=model.make_cg_iter("layered", n, ee, dtype),
                args=model.cg_iter_arg_specs(n, ee, dtype),
            )
        )
    return entries


def build(out_dir: str, entries=None, verbose: bool = True) -> dict:
    """Lower all entries into ``out_dir`` and write ``manifest.json``."""
    os.makedirs(out_dir, exist_ok=True)
    entries = entries if entries is not None else default_entries()
    manifest = {"format": 1, "generated_unix": int(time.time()), "artifacts": []}
    for e in entries:
        t0 = time.time()
        # Single-output kinds get an array root (fast raw download);
        # cg_iter returns (w, pap) and keeps the tuple root.
        tupled = e["kind"] == "cg_iter"
        text = _lower(e["fn"], e["args"], return_tuple=tupled)
        fname = e["name"] + ".hlo.txt"
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {
                "name": e["name"],
                "kind": e["kind"],
                "variant": e["variant"],
                "n": e["n"],
                "chunk": e["chunk"],
                "dtype": e["dtype"],
                "file": fname,
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
                "num_args": len(e["args"]),
                "arg_shapes": [list(a.shape) for a in e["args"]],
                "tupled": tupled,
            }
        )
        if verbose:
            print(f"  {e['name']:36s} {len(text):>9d} chars  {time.time()-t0:5.1f}s")
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    if verbose:
        print(f"wrote {len(manifest['artifacts'])} artifacts + {mpath}")
    return manifest


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts", help="output directory")
    p.add_argument("--n", type=int, default=DEFAULT_N, help="GLL points per dim")
    p.add_argument("--chunk", type=int, default=DEFAULT_CHUNK, help="elements per launch")
    p.add_argument(
        "--quick", action="store_true", help="only the paper configuration (CI-fast)"
    )
    args = p.parse_args()
    if args.quick:
        entries = default_entries(args.n, args.chunk, extra_ns=(), perf_chunks=())
    else:
        entries = default_entries(args.n, args.chunk)
    build(args.out, entries)


if __name__ == "__main__":
    main()
